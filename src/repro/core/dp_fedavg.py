"""DP-FedAvg with fixed-size federated rounds — Algorithm 1 of the paper.

Server side of the mechanism, architecture-agnostic over update pytrees:

    Δ̄ = (1/qN) Σ_k clip_S(Δ_k)          (clip → weighted average)
    θ' = θ + ServerOpt(Δ̄ + N(0, I·σ²))   with σ = zS/(qN)

Two aggregation entry points are provided:
  * :func:`aggregate` — takes the round's per-user updates stacked on a
    leading axis (simulation path, small scale);
  * :func:`finalize_round` — takes an already-accumulated clipped *sum*
    (the production-shape path: `launch.steps.fed_train_step` accumulates
    the clipped sum with `lax.scan` over client microbatches so per-user
    updates never coexist in memory).

Noise is always sampled in f32 (see `utils.pytree.tree_noise`).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import DPConfig
from repro.core.clipping import clip_by_global_norm
from repro.core.server_optim import ServerOptState, apply_update
from repro.utils.pytree import tree_noise


class RoundStats(NamedTuple):
    mean_update_norm: jax.Array   # mean pre-clip ‖Δ_k‖
    frac_clipped: jax.Array       # fraction of users whose update was clipped
    noise_std: jax.Array          # σ actually applied


def clip_user_update(update, dp: DPConfig):
    """Algorithm 1 UserUpdate final line: Δ·min(1, S/‖Δ‖)."""
    return clip_by_global_norm(update, dp.clip_norm)


def aggregate(user_updates, key, dp: DPConfig, n_clients: int = None):
    """user_updates: pytree with leading user axis. → (noised mean Δ, stats)."""
    n = n_clients or jax.tree_util.tree_leaves(user_updates)[0].shape[0]
    clipped, norms, was_clipped = jax.vmap(
        lambda u: clip_user_update(u, dp))(user_updates)
    total = jax.tree_util.tree_map(
        lambda l: jnp.sum(l.astype(jnp.float32), axis=0), clipped)
    return finalize_round(total, n, key, dp, stats=(jnp.mean(norms),
                                                    jnp.mean(was_clipped)))


def finalize_round(clipped_sum, n_clients, key, dp: DPConfig, stats=None):
    """clipped_sum: Σ_k clip_S(Δ_k) (f32 pytree). Divide by the round size,
    add N(0, σ²) with σ = z·S/round_size, return (Δ̄, RoundStats)."""
    n = jnp.asarray(n_clients, jnp.float32)
    sigma = dp.noise_multiplier * dp.clip_norm / n
    mean = jax.tree_util.tree_map(lambda l: l / n, clipped_sum)
    noise = tree_noise(key, mean, sigma)
    noised = jax.tree_util.tree_map(jnp.add, mean, noise)
    mean_norm, frac = stats if stats is not None else (
        jnp.zeros(()), jnp.zeros(()))
    return noised, RoundStats(mean_norm, frac, sigma)


def server_step(params, opt_state: ServerOptState, delta, dp: DPConfig):
    """θ ← θ + ServerOpt(Δ̄)."""
    return apply_update(params, delta, opt_state, dp)


def dp_fedavg_round(params, opt_state, user_updates, key, dp: DPConfig):
    """Full Algorithm-1 server round from stacked per-user updates."""
    delta, stats = aggregate(user_updates, key, dp)
    params, opt_state = server_step(params, opt_state, delta, dp)
    return params, opt_state, stats
