"""Federated Secret Sharer — the paper's §II-B / §IV measurement framework.

Canaries are 5-word sequences with each word drawn u.a.r. from the model
vocabulary, parameterized by (n_u = #secret-sharing users, n_e = #copies per
user). Two extraction measures:

* Random Sampling (RS) rank [CLK+18]: rank of the canary continuation's
  log-perplexity P_θ(s|p) among |R| random continuations (paper: |R|=2e6).
* Beam Search (BS): is the canary among the top-5 width-5 continuations of
  its 2-word prefix.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model

CANARY_LEN = 5
PREFIX_LEN = 2


@dataclass(frozen=True)
class Canary:
    tokens: Tuple[int, ...]   # full 5-word canary (token ids)
    n_u: int                  # users sharing this canary
    n_e: int                  # copies per user

    @property
    def prefix(self) -> Tuple[int, ...]:
        return self.tokens[:PREFIX_LEN]

    @property
    def continuation(self) -> Tuple[int, ...]:
        return self.tokens[PREFIX_LEN:]


def make_canaries(key, vocab: int,
                  grid: Sequence[Tuple[int, int]] = ((1, 1), (1, 14), (1, 200),
                                                     (4, 1), (4, 14), (4, 200),
                                                     (16, 1), (16, 14), (16, 200)),
                  per_config: int = 3, length: int = CANARY_LEN) -> List[Canary]:
    """``per_config`` canaries for each (n_u, n_e) configuration in ``grid``
    (the paper's §IV-A setup is the default: 3 canaries × 9 configs = 27).

    Canaries whose ``PREFIX_LEN``-word prefix collides with an earlier
    canary's are redrawn: beam-search extraction conditions on the prefix, so
    two canaries sharing one would compete for the same beam and the
    per-canary extracted/not-extracted verdict would be ill-defined.
    """
    total = len(grid) * per_config
    space = vocab ** PREFIX_LEN
    if total > space:
        raise ValueError(
            f"cannot draw {total} canaries with distinct {PREFIX_LEN}-word "
            f"prefixes from a {vocab}-word vocabulary ({space} prefixes)")
    canaries = []
    seen = set()
    for (n_u, n_e) in grid:
        for _ in range(per_config):
            for _attempt in range(10_000):
                key, sub = jax.random.split(key)
                toks = tuple(int(t) for t in
                             jax.random.randint(sub, (length,), 0, vocab))
                if toks[:PREFIX_LEN] not in seen:
                    break
            else:
                raise RuntimeError("make_canaries: could not draw a "
                                   "collision-free prefix in 10k attempts")
            seen.add(toks[:PREFIX_LEN])
            canaries.append(Canary(toks, n_u, n_e))
    return canaries


def canary_matrix(canaries: Sequence[Canary]) -> np.ndarray:
    """Stack canary token sequences into a (K, CANARY_LEN) int32 matrix —
    the batched-scoring layout used by :func:`score_canaries`."""
    return np.asarray([c.tokens for c in canaries], np.int32)


# ---------------------------------------------------------------------------
# log-perplexity scoring
# ---------------------------------------------------------------------------


def _batched_log_perplexity(params, seqs, model: Model, prefix_len: int):
    """seqs: (B, L) full sequences (prefix + continuation).
    Returns (B,) Σ_i −log Pr(s_i | p, s_<i) over the continuation positions."""
    logits = model.forward(params, {"tokens": seqs})         # (B, L, Vpad)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    # next-token prediction: logits at position i predict token i+1
    targets = seqs[:, 1:]
    lp = jnp.take_along_axis(logp[:, :-1, :], targets[..., None],
                             axis=-1)[..., 0]                # (B, L-1)
    cont = lp[:, prefix_len - 1:]
    return -jnp.sum(cont, axis=-1)


def score_canaries(model: Model, params, canary_tokens,
                   prefix_len: int = PREFIX_LEN):
    """Vectorized canary log-perplexity kernel: (K, L) token batch →
    (K,) Σ −log Pr(continuation | prefix).

    Pure traced JAX (no jit wrapper, no host transfer), so it composes both
    ways the harness needs it: as the body of an in-scan eval hook
    (memorization-vs-round curves via ``SimEngine(eval_fn=...)``) and, jitted
    by the caller, as the chunk kernel for large-|R| Random-Sampling rank
    scoring (:func:`random_sampling_ranks`).
    """
    return _batched_log_perplexity(params, jnp.asarray(canary_tokens),
                                   model, prefix_len)


def canary_eval_fn(model: Model, canaries: Sequence[Canary]):
    """Build a ``SimEngine`` eval hook scoring all ``canaries`` each call:
    ``eval_fn(params, round_idx) -> {"canary_logppl": (K,) f32}``."""
    toks = jnp.asarray(canary_matrix(canaries))

    def eval_fn(params, round_idx):
        return {"canary_logppl": score_canaries(model, params, toks)}

    return eval_fn


def log_perplexity(model: Model, params, sequences: np.ndarray,
                   prefix_len: int = PREFIX_LEN, batch_size: int = 512) -> np.ndarray:
    """Score many (prefix+continuation) sequences; returns np.float32 (N,)."""
    fn = jax.jit(partial(_batched_log_perplexity, model=model,
                         prefix_len=prefix_len))
    out = []
    n = sequences.shape[0]
    for i in range(0, n, batch_size):
        chunk = sequences[i:i + batch_size]
        pad = batch_size - chunk.shape[0]
        if pad:
            chunk = np.concatenate([chunk, np.zeros((pad, chunk.shape[1]),
                                                    chunk.dtype)])
        scores = np.asarray(fn(params, jnp.asarray(chunk)))
        out.append(scores[:batch_size - pad if pad else batch_size])
    return np.concatenate(out)


def random_sampling_ranks(model: Model, params, canaries: Sequence[Canary],
                          key, n_samples: int = 100_000,
                          batch_size: int = 1024) -> np.ndarray:
    """rank_θ(c; R) = |{r ∈ R : P_θ(r|p) < P_θ(s|p)}| for *all* canaries at
    once (paper §IV-A.1). One shared pool of |R| random continuations is
    scored behind every canary's prefix in (K·batch_size)-sequence chunks,
    so sweep-scale |R| (the paper uses 2·10⁶) costs one jit compile and
    K·|R|/batch_size batched forward passes. Returns int64 (K,) ranks."""
    K = len(canaries)
    vocab = model.cfg.vocab
    cont_len = CANARY_LEN - PREFIX_LEN
    toks = canary_matrix(canaries)
    prefixes = jnp.asarray(toks[:, :PREFIX_LEN])

    scorer = jax.jit(partial(score_canaries, model))
    canary_scores = np.asarray(scorer(params, jnp.asarray(toks)))

    @jax.jit
    def chunk_scores(p, conts):                       # conts: (b, cont_len)
        b = conts.shape[0]
        seqs = jnp.concatenate(
            [jnp.broadcast_to(prefixes[:, None], (K, b, PREFIX_LEN)),
             jnp.broadcast_to(conts[None], (K, b, cont_len))], axis=-1)
        return score_canaries(model, p, seqs.reshape(K * b, CANARY_LEN)
                              ).reshape(K, b)

    ranks = np.zeros(K, np.int64)
    for i in range(0, n_samples, batch_size):
        b = min(batch_size, n_samples - i)
        key, sub = jax.random.split(key)
        conts = jax.random.randint(sub, (batch_size, cont_len), 0, vocab)
        scores = np.asarray(chunk_scores(params, conts))[:, :b]
        ranks += (scores < canary_scores[:, None]).sum(axis=1)
    return ranks


def random_sampling_rank(model: Model, params, canary: Canary, key,
                         n_samples: int = 100_000,
                         batch_size: int = 1024) -> int:
    """Single-canary convenience wrapper over :func:`random_sampling_ranks`."""
    return int(random_sampling_ranks(model, params, [canary], key,
                                     n_samples, batch_size)[0])


# ---------------------------------------------------------------------------
# beam search extraction
# ---------------------------------------------------------------------------


def beam_search(model: Model, params, prefix: Sequence[int], total_len: int,
                width: int = 5) -> List[Tuple[int, ...]]:
    """Greedy beam search continuation of ``prefix`` to ``total_len`` words.
    Returns the top-``width`` sequences (paper §IV-A.2)."""
    vocab = model.cfg.vocab
    beams = [(tuple(prefix), 0.0)]
    fwd = jax.jit(lambda p, t: model.forward(p, {"tokens": t}))
    for _ in range(total_len - len(prefix)):
        seqs = jnp.asarray([b[0] for b in beams], jnp.int32)
        logits = fwd(params, seqs)[:, -1, :]
        logp = np.asarray(jax.nn.log_softmax(
            logits.astype(jnp.float32), axis=-1))[:, :vocab]
        cand = []
        for (toks, score), row in zip(beams, logp):
            top = np.argpartition(-row, width)[:width]
            for t in top:
                cand.append((toks + (int(t),), score + float(row[t])))
        cand.sort(key=lambda x: -x[1])
        beams = cand[:width]
    return [b[0] for b in beams]


def canary_extracted(model: Model, params, canary: Canary,
                     width: int = 5) -> bool:
    """BS check: canary among top-5 5-word continuations of its 2-word prefix."""
    tops = beam_search(model, params, canary.prefix, CANARY_LEN, width)
    return tuple(canary.tokens) in [tuple(t) for t in tops]
