"""Per-user update clipping (Algorithm 1, UserUpdate's final line).

``clip_by_global_norm`` is the reference pytree path; the Pallas-backed path
(`repro.kernels.dp_clip`) fuses the square-accumulate / clip-scale /
sum-accumulate over flat f32 vectors and is validated against this.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.pytree import tree_global_norm


def clip_factor(norm, clip_norm: float):
    """min(1, S/‖Δ‖) — the paper's clip (Algorithm 1)."""
    return jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-12))


def clip_by_global_norm(update, clip_norm: float):
    """Returns (clipped_update, pre_clip_norm, was_clipped)."""
    norm = tree_global_norm(update)
    factor = clip_factor(norm, clip_norm)
    clipped = jax.tree_util.tree_map(
        lambda l: (l.astype(jnp.float32) * factor).astype(l.dtype), update)
    return clipped, norm, (factor < 1.0).astype(jnp.float32)
