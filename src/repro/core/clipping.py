"""Per-user update clipping (Algorithm 1, UserUpdate's final line).

``clip_by_global_norm`` is the validated reference pytree path.
``clip_accumulate_tree`` is the *streaming* form used by the chunked cohort
accumulator: one clip→fold step ``acc ← acc + scale·min(1, S/‖Δ‖)·Δ`` with
two interchangeable implementations —

* ``"fused"`` — the Pallas flat-parameter kernels
  (`repro.kernels.dp_clip`): one fused sum-of-squares sweep and one fused
  scale-and-accumulate sweep per update (compiled Pallas on TPU, the Pallas
  interpreter on CPU — same kernel bodies either way);
* ``"tree"`` — the pytree reference built on :func:`clip_by_global_norm`'s
  arithmetic, kept as the independent oracle the fused path is validated
  against.

Both paths compute the pre-clip norm, the clip factor, and the was-clipped
flag with identical formulas; they differ only in the association of the
sum-of-squares reduction (tiled kernel vs per-leaf ``jnp.sum``), so they
agree to float tolerance, and each is individually deterministic — the
bit-exact ``cohort_chunk``/shard parity of the engine holds within either
path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dp_clip import ops as dp_clip_ops
from repro.utils.pytree import tree_global_norm

CLIP_PATHS = ("fused", "tree")


def clip_factor(norm, clip_norm: float):
    """min(1, S/‖Δ‖) — the paper's clip (Algorithm 1)."""
    return jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-12))


def clip_by_global_norm(update, clip_norm: float):
    """Returns (clipped_update, pre_clip_norm, was_clipped)."""
    norm = tree_global_norm(update)
    factor = clip_factor(norm, clip_norm)
    clipped = jax.tree_util.tree_map(
        lambda l: (l.astype(jnp.float32) * factor).astype(l.dtype), update)
    return clipped, norm, (factor < 1.0).astype(jnp.float32)


def clip_accumulate_tree(acc, update, clip_norm: float, scale=None,
                         *, clip_path: str = "fused", interpret=None):
    """One streaming clip→accumulate step over f32 pytrees.

    ``acc ← acc + scale·min(1, S/‖Δ‖)·Δ`` — ``scale`` (optional traced
    scalar) carries the 0/1 slot mask, so a masked slot contributes exactly
    ±0 to the accumulator (the DP "excluded slots contribute nothing"
    invariant). Returns ``(new_acc, pre_clip_norm, was_clipped)`` where the
    norm/flag describe the *unmasked* update (callers mask the stats
    themselves so the denominator stays the realized round size).
    """
    if clip_path not in CLIP_PATHS:
        raise ValueError(f"clip_path must be one of {CLIP_PATHS}, "
                         f"got {clip_path!r}")
    if clip_path == "fused":
        new_acc, norm = dp_clip_ops.clip_accumulate(
            acc, update, clip_norm, scale, interpret=interpret)
        factor = clip_factor(norm, clip_norm)
    else:
        norm = tree_global_norm(update)
        factor = clip_factor(norm, clip_norm)
        f = factor if scale is None else factor * scale
        new_acc = jax.tree_util.tree_map(
            lambda a, d: a + f * d.astype(jnp.float32), acc, update)
    return new_acc, norm, (factor < 1.0).astype(jnp.float32)
