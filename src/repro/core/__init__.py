"""The paper's primary contribution: DP-FedAvg with fixed-size federated
rounds (Algorithm 1), its RDP accountant, and the Federated Secret Sharer
memorization measurement."""
from repro.core.accountant import MomentsAccountant, table5_epsilon
from repro.core.clipping import clip_by_global_norm
from repro.core.dp_fedavg import (RoundStats, aggregate, dp_fedavg_round,
                                  finalize_round, server_step)
from repro.core.secret_sharer import (Canary, beam_search, canary_eval_fn,
                                      canary_extracted, canary_matrix,
                                      log_perplexity, make_canaries,
                                      random_sampling_rank,
                                      random_sampling_ranks, score_canaries)
from repro.core.server_optim import ServerOptState, apply_update, init_state

__all__ = [
    "MomentsAccountant", "table5_epsilon", "clip_by_global_norm",
    "RoundStats", "aggregate", "dp_fedavg_round", "finalize_round",
    "server_step", "Canary", "beam_search", "canary_eval_fn",
    "canary_extracted", "canary_matrix", "log_perplexity", "make_canaries",
    "random_sampling_rank", "random_sampling_ranks", "score_canaries",
    "ServerOptState", "apply_update", "init_state",
]
