"""RDP moments accountant for the subsampled Gaussian mechanism.

The paper (§V-A) accounts privacy via: per-round RDP of the subsampled
Gaussian [Mir17; MTZ19; WBK19] → T-fold composition [Mir17, Prop. 1] → (ε,δ)
conversion [Mir17, Prop. 3 / the tightened Balle et al. bound].

We implement the Poisson-subsampled Gaussian RDP in stable log-space (the
binomial expansion over integer orders α):

    RDP(α) = 1/(α−1) · log Σ_{k=0}^{α} C(α,k)(1−q)^{α−k} q^k · e^{k(k−1)/(2z²)}

The paper's Table 5 uses fixed-size sampling without replacement (WBK19);
at these parameters (q ≤ 0.01, z = 0.8) the Poisson bound is numerically
close — the comparison is part of `benchmarks/bench_accounting.py`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterable, Sequence

DEFAULT_ORDERS = tuple(range(2, 129)) + tuple(range(130, 512, 4))


def _log_binom(n: int, k: int) -> float:
    return (math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1))


def _logsumexp(xs: Iterable[float]) -> float:
    xs = list(xs)
    m = max(xs)
    if m == -math.inf:
        return -math.inf
    return m + math.log(sum(math.exp(x - m) for x in xs))


def rdp_subsampled_gaussian(q: float, z: float, order: int) -> float:
    """RDP ε_α of one round of the Poisson-subsampled Gaussian mechanism."""
    if q == 0.0:
        return 0.0
    if z == 0.0:
        return math.inf  # no noise ⇒ no DP guarantee
    if q == 1.0:
        return order / (2 * z * z)
    if order <= 1 or int(order) != order:
        raise ValueError("integer orders > 1 only")
    a = int(order)
    log_terms = []
    for k in range(a + 1):
        log_coef = _log_binom(a, k) + k * math.log(q) + (a - k) * math.log1p(-q)
        log_terms.append(log_coef + (k * (k - 1)) / (2 * z * z))
    return _logsumexp(log_terms) / (a - 1)


def rdp_subsampled_gaussian_wor(q: float, z: float, order: int) -> float:
    """RDP bound for the *fixed-size sampling without replacement* subsampled
    Gaussian [WBK19, Thm 9 simplified for a Gaussian base mechanism] — the
    sampling scheme the paper actually deploys (Algorithm 1) and accounts
    with. Replace-one adjacency; the ε(∞)-dependent factors collapse to the
    min{…}=2 / 4(e^{ε(2)}−1) branches since the Gaussian has ε(∞)=∞."""
    if q == 0.0:
        return 0.0
    if z == 0.0:
        return math.inf  # no noise ⇒ no DP guarantee
    a = int(order)
    if a <= 1 or a != order:
        raise ValueError("integer orders > 1 only")
    gauss = lambda j: j / (2 * z * z)
    terms = [0.0]  # log(1)
    terms.append(_log_binom(a, 2) + 2 * math.log(q) + math.log(4.0)
                 + math.log(math.expm1(gauss(2))))
    for j in range(3, a + 1):
        terms.append(_log_binom(a, j) + j * math.log(q) + math.log(2.0)
                     + (j - 1) * gauss(j))
    return _logsumexp(terms) / (a - 1)


def compose(rdp_per_round: Sequence[float], rounds: int) -> list:
    """[Mir17 Prop. 1]: RDP composes additively order-wise."""
    return [r * rounds for r in rdp_per_round]


def eps_from_rdp(orders: Sequence[int], rdp: Sequence[float],
                 delta: float) -> tuple:
    """Tight RDP→DP conversion (Balle–Barthe–Gaboardi–Hsu–Sato '20 form used
    by tf-privacy): ε = RDP(α) + log((α−1)/α) − (log δ + log α)/(α−1)."""
    best_eps, best_order = math.inf, None
    for a, r in zip(orders, rdp):
        if a <= 1:
            continue
        eps = r + math.log((a - 1) / a) - (math.log(delta) + math.log(a)) / (a - 1)
        if eps < best_eps:
            best_eps, best_order = eps, a
    return best_eps, best_order


@dataclass
class MomentsAccountant:
    """Tracks composed RDP over federated rounds (Algorithm 1's 𝓜)."""

    q: float                   # round participation fraction (qN/N)
    noise_multiplier: float    # z
    orders: Sequence[int] = DEFAULT_ORDERS
    sampling: str = "poisson"  # "poisson" (MTZ19) | "wor" (WBK19, the paper's)

    def __post_init__(self):
        fn = (rdp_subsampled_gaussian if self.sampling == "poisson"
              else rdp_subsampled_gaussian_wor)
        self._per_round = [fn(self.q, self.noise_multiplier, a)
                           for a in self.orders]
        self._rounds = 0

    def step(self, n: int = 1) -> None:
        self._rounds += n

    def record_round(self, committed: bool = True) -> None:
        """Record one round under the production fault protocol: an aborted
        round (survivors < report goal) released *nothing* — the noised sum
        was never applied or published — so it composes nothing and spends
        zero budget. Only committed rounds advance the composition count."""
        if committed:
            self._rounds += 1

    def restore_rounds(self, rounds: int) -> None:
        """Reset the composition count from a durable run-state snapshot
        (crash resume). The accountant is otherwise stateless: per-round RDP
        is recomputed from (q, z) at construction."""
        if rounds < 0:
            raise ValueError(f"rounds must be >= 0, got {rounds}")
        self._rounds = int(rounds)

    @property
    def rounds(self) -> int:
        return self._rounds

    def get_epsilon(self, delta: float, rounds: int = None) -> float:
        t = self._rounds if rounds is None else rounds
        rdp = compose(self._per_round, t)
        eps, _ = eps_from_rdp(self.orders, rdp, delta)
        return eps


def table5_epsilon(population: int, clients_per_round: int = 20_000,
                   noise_multiplier: float = 0.8, rounds: int = 2_000,
                   delta: float = None, sampling: str = "wor") -> float:
    """Reproduce one row of the paper's Table 5 (hypothetical ε upper bounds
    for the production run: T=2000, qN=20000, z=0.8, δ=N^-1.1)."""
    q = clients_per_round / population
    if delta is None:
        delta = population ** -1.1
    acc = MomentsAccountant(q=q, noise_multiplier=noise_multiplier,
                            sampling=sampling)
    acc.step(rounds)
    return acc.get_epsilon(delta)
