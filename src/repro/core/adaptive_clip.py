"""Adaptive clipping [TAM19 — Thakkar, Andrew, McMahan, "Differentially
Private Learning with Adaptive Clipping"], cited by the paper (§I) as part
of the same program. BEYOND-PAPER feature: instead of a fixed S, track the
γ-quantile of per-user update norms with a DP-protected geometric update:

    b_t   = (1/n) Σ_k 1[‖Δ_k‖ ≤ S_t] + N(0, σ_b²)   (noisy clipped fraction)
    S_t+1 = S_t · exp(−η_C (b_t − γ))

The indicator sum has sensitivity 1 per user, so the noisy fraction costs a
small additional privacy budget (accounted as a second Gaussian mechanism
with noise multiplier z_b; the paper's Fig. 1 shows why this matters — the
right S drifts over training as update norms shrink).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdaptiveClipState(NamedTuple):
    clip_norm: jax.Array      # S_t (f32 scalar)
    target_quantile: float    # γ (paper's ablation: clip ~90% of clients)
    lr: float                 # η_C
    noise_multiplier_b: float  # z_b for the fraction estimate


def init_adaptive_clip(initial_clip: float = 0.8, target_quantile: float = 0.9,
                       lr: float = 0.2, noise_multiplier_b: float = 10.0):
    return AdaptiveClipState(jnp.asarray(initial_clip, jnp.float32),
                             target_quantile, lr, noise_multiplier_b)


def update_clip_norm(state: AdaptiveClipState, frac_below: jax.Array,
                     n_clients: int, key) -> AdaptiveClipState:
    """frac_below: exact fraction of users with ‖Δ_k‖ ≤ S_t this round.
    Applies the DP noise to the fraction, then the geometric update."""
    sigma_b = state.noise_multiplier_b / n_clients
    noisy = frac_below + sigma_b * jax.random.normal(key, (), jnp.float32)
    new_s = state.clip_norm * jnp.exp(
        -state.lr * (noisy - state.target_quantile))
    return state._replace(clip_norm=new_s)


def adaptive_rounds(norms_per_round, n_clients: int, key,
                    state: AdaptiveClipState):
    """Simulation helper: run the adaptation over a sequence of per-round
    user-norm arrays; returns the S_t trajectory."""
    traj = [float(state.clip_norm)]
    for norms in norms_per_round:
        key, sub = jax.random.split(key)
        frac = jnp.mean((jnp.asarray(norms) <= state.clip_norm)
                        .astype(jnp.float32))
        state = update_clip_norm(state, frac, n_clients, sub)
        traj.append(float(state.clip_norm))
    return state, traj
