"""Server optimizers for DP-FedAvg (paper Table 1 / Table 6 ablation).

The paper's production configuration is Nesterov momentum with η_s=1.0,
μ=0.99; plain SGD and Adam are implemented for the Table 6 ablation. All
state/updates are f32 pytrees; the "gradient" is the *negated* averaged model
delta (server update direction = +Δ), so we feed Δ directly and ADD.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import DPConfig
from repro.utils.pytree import tree_zeros_like


class ServerOptState(NamedTuple):
    momentum: object   # pytree or None-like zeros
    nu: object         # adam second moment
    count: object      # scalar int32


def init_state(params) -> ServerOptState:
    f32 = lambda t: jax.tree_util.tree_map(
        lambda l: jnp.zeros(l.shape, jnp.float32), t)
    return ServerOptState(momentum=f32(params), nu=f32(params),
                          count=jnp.zeros((), jnp.int32))


def apply_update(params, delta, state: ServerOptState, dp: DPConfig):
    """θ ← θ + ServerOpt(Δ). Returns (new_params, new_state)."""
    lr = dp.server_lr
    if dp.server_opt == "sgd":
        new_params = jax.tree_util.tree_map(
            lambda p, d: (p.astype(jnp.float32) + lr * d).astype(p.dtype),
            params, delta)
        return new_params, state._replace(count=state.count + 1)

    if dp.server_opt == "momentum":
        mu = dp.server_momentum
        new_m = jax.tree_util.tree_map(
            lambda m, d: mu * m + d.astype(jnp.float32), state.momentum, delta)
        if dp.nesterov:
            step = jax.tree_util.tree_map(
                lambda m, d: mu * m + d.astype(jnp.float32), new_m, delta)
        else:
            step = new_m
        new_params = jax.tree_util.tree_map(
            lambda p, s: (p.astype(jnp.float32) + lr * s).astype(p.dtype),
            params, step)
        return new_params, state._replace(momentum=new_m,
                                          count=state.count + 1)

    if dp.server_opt == "adam":
        b1, b2, eps = 0.9, 0.999, dp.adam_eps
        cnt = state.count + 1
        new_m = jax.tree_util.tree_map(
            lambda m, d: b1 * m + (1 - b1) * d.astype(jnp.float32),
            state.momentum, delta)
        new_v = jax.tree_util.tree_map(
            lambda v, d: b2 * v + (1 - b2) * jnp.square(d.astype(jnp.float32)),
            state.nu, delta)
        c = cnt.astype(jnp.float32)
        bc1 = 1.0 - b1 ** c
        bc2 = 1.0 - b2 ** c
        new_params = jax.tree_util.tree_map(
            lambda p, m, v: (p.astype(jnp.float32)
                             + lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
                             ).astype(p.dtype),
            params, new_m, new_v)
        return new_params, ServerOptState(new_m, new_v, cnt)

    raise ValueError(f"unknown server_opt {dp.server_opt!r}")
