"""Unified model interface: every family exposes the same six functions.

The DP-FedAvg machinery and the launch layer only ever touch this interface,
so the paper's technique is architecture-agnostic by construction.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

from repro.configs.base import ModelConfig


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable[..., Any]                 # (key) -> params
    forward: Callable[..., Any]              # (params, batch) -> logits (B,S,Vpad)
    loss_fn: Callable[..., Any]              # (params, batch) -> scalar f32
    init_cache: Callable[..., Any]           # (batch_size, max_len) -> cache pytree
    prefill: Callable[..., Any]              # (params, batch) -> (logits, cache)
    decode_step: Callable[..., Any]          # (params, tokens (B,), cache) -> (logits (B,Vpad), cache)

# Serving contract (repro/serve): a model is *continuous-batching capable*
# when every decode-cache leaf is per-row (leading dim = batch) and
# decode_step treats rows independently — the serving engine then admits/
# evicts sessions by scattering their state into individual cache slots.
# The CIFG-LSTM cache (h, c, pos — all (B, ...)) satisfies this; ring-buffer
# KV caches with a shared scalar position do not (yet).
#
# Length-aware prefill (optional): a model that honors batch["length"]
# ((B,) int32 true prompt lengths inside right-padded tokens) — returning
# state and last-position logits *bitwise identical* to an unpadded prefill
# of that length — gets bucket-padded admission in the serving engine (one
# prefill compile per power-of-two length instead of per distinct length).
# The engine verifies the contract with a behavioral probe at construction
# and falls back to exact-length prefills when it doesn't hold.
