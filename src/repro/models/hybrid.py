"""Zamba2-style hybrid [arXiv:2411.15242]: a Mamba-2 backbone with a single
*shared* GQA attention+MLP block interleaved every ``hybrid_attn_every``
mamba layers (weights shared across sites, distinct KV cache per site).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models.api import Model
from repro.models.embed import embed_tokens, embedding_init, lm_logits


def n_attn_sites(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.hybrid_attn_every == 0, (
        cfg.n_layers, cfg.hybrid_attn_every)
    return cfg.n_layers // cfg.hybrid_attn_every


def init(key, cfg: ModelConfig):
    ke, kl, ka, km = jax.random.split(key, 4)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    return {
        "embed": embedding_init(ke, cfg),
        "mamba_layers": jax.vmap(partial(M._layer_init, cfg=cfg))(layer_keys),
        "shared_attn": {
            "ln1": L.norm_init(cfg.d_model, cfg.norm),
            "attn": L.gqa_init(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim),
            "ln2": L.norm_init(cfg.d_model, cfg.norm),
            "mlp": L.mlp_init(km, cfg.d_model, cfg.d_ff, cfg.act),
        },
        "ln_f": L.norm_init(cfg.d_model, "rmsnorm"),
    }


def _group_params(params, cfg: ModelConfig):
    """Reshape stacked (n_layers, ...) mamba params → (sites, every, ...)."""
    g, e = n_attn_sites(cfg), cfg.hybrid_attn_every
    return jax.tree_util.tree_map(
        lambda a: a.reshape((g, e) + a.shape[1:]), params["mamba_layers"])


def _shared_attn_fwd(x, sp, cfg: ModelConfig, positions, *, window):
    h = L.norm(x, sp["ln1"], cfg.norm)
    q, k, v = L.gqa_project(h, sp["attn"], cfg.n_heads, cfg.n_kv_heads,
                            cfg.head_dim, positions, cfg.rope_theta)
    a = L.attention(q, k, v, q_positions=positions, kv_positions=positions,
                    causal=True, window=window)
    B, S, _, _ = a.shape
    x = x + a.reshape(B, S, -1) @ sp["attn"]["wo"].astype(x.dtype)
    h2 = L.norm(x, sp["ln2"], cfg.norm)
    x = x + L.mlp(h2, sp["mlp"], cfg.act)
    return x, (k, v)


def forward(params, batch, cfg: ModelConfig, *, remat: bool = False,
            collect_cache: bool = False):
    cd = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], batch["tokens"], cd)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    grouped = _group_params(params, cfg)
    sp = params["shared_attn"]

    def group_body(carry, glp):
        def mamba_body(c, lp):
            h = L.norm(c, lp["ln"], "rmsnorm")
            if collect_cache:
                out, h_fin, tail = M.mixer_fwd(h, lp["mixer"], cfg,
                                               return_state=True)
                return c + out, (h_fin, tail)
            return c + M.mixer_fwd(h, lp["mixer"], cfg), None

        y, mcache = jax.lax.scan(mamba_body, carry, glp)
        y, kv = _shared_attn_fwd(y, sp, cfg, positions, window=cfg.attn_window)
        return y, (mcache, kv) if collect_cache else None

    fn = jax.checkpoint(group_body) if remat else group_body
    x, caches = jax.lax.scan(fn, x, grouped)
    x = L.norm(x, params["ln_f"], "rmsnorm")
    logits = lm_logits(params["embed"], x)
    return (logits, caches) if collect_cache else logits


def loss_fn(params, batch, cfg: ModelConfig, *, remat: bool = True):
    logits = forward(params, batch, cfg, remat=remat)
    return L.lm_loss(logits, batch["labels"], cfg.vocab, batch.get("mask"))


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    di, N, H = M.d_inner(cfg), cfg.ssm_state, cfg.ssm_heads
    hp = di // H
    W = cfg.ssm_conv_width
    g = n_attn_sites(cfg)
    cd = jnp.dtype(cfg.compute_dtype)
    Lr = cfg.n_layers
    return {
        "ssm": jnp.zeros((Lr, batch_size, H, hp, N), jnp.float32),
        "conv_x": jnp.zeros((Lr, batch_size, W - 1, di), cd),
        "conv_B": jnp.zeros((Lr, batch_size, W - 1, N), cd),
        "conv_C": jnp.zeros((Lr, batch_size, W - 1, N), cd),
        "k": jnp.zeros((g, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim), cd),
        "v": jnp.zeros((g, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim), cd),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, batch, cfg: ModelConfig, *, max_len: int = None):
    from repro.models.transformer import _pad_kv
    g, e = n_attn_sites(cfg), cfg.hybrid_attn_every
    logits, ((h_fins, tails), (ks, vs)) = forward(params, batch, cfg,
                                                  collect_cache=True)
    cd = jnp.dtype(cfg.compute_dtype)
    flat = lambda a: a.reshape((g * e,) + a.shape[2:])
    cx, cB, cC = tails
    cache = {"ssm": flat(h_fins), "conv_x": flat(cx).astype(cd),
             "conv_B": flat(cB).astype(cd), "conv_C": flat(cC).astype(cd),
             "k": _pad_kv(ks, max_len), "v": _pad_kv(vs, max_len),
             "pos": jnp.asarray(batch["tokens"].shape[1], jnp.int32)}
    return logits[:, -1, :], cache


def decode_step(params, tokens, cache, cfg: ModelConfig):
    cd = jnp.dtype(cfg.compute_dtype)
    g, e = n_attn_sites(cfg), cfg.hybrid_attn_every
    pos = cache["pos"]
    x = embed_tokens(params["embed"], tokens[:, None], cd)
    grouped = _group_params(params, cfg)
    sp = params["shared_attn"]
    max_len = cache["k"].shape[2]
    kv_positions = jnp.arange(max_len, dtype=jnp.int32)
    q_positions = pos[None]
    reshape_g = lambda a: a.reshape((g, e) + a.shape[1:])
    ssm_g = reshape_g(cache["ssm"])
    cx_g, cB_g, cC_g = (reshape_g(cache["conv_x"]), reshape_g(cache["conv_B"]),
                        reshape_g(cache["conv_C"]))

    def group_body(carry, inp):
        glp, ssm_l, cx_l, cB_l, cC_l, kc, vc = inp

        def mamba_body(c, lpc):
            lp, h, cx, cB, cC = lpc
            hin = L.norm(c, lp["ln"], "rmsnorm")
            out, h_new, (cxn, cBn, cCn) = M.mixer_step(hin, lp["mixer"], cfg,
                                                       h, (cx, cB, cC))
            return c + out, (h_new, cxn.astype(cxn.dtype), cBn, cCn)

        y, (hs, cxs, cBs, cCs) = jax.lax.scan(
            mamba_body, carry, (glp, ssm_l, cx_l, cB_l, cC_l))
        h = L.norm(y, sp["ln1"], cfg.norm)
        q, k, v = L.gqa_project(h, sp["attn"], cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, q_positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, pos, 0, 0))
        a = L.attention(q, kc, vc, q_positions=q_positions,
                        kv_positions=kv_positions, kv_len=pos + 1,
                        causal=True, window=cfg.attn_window)
        B = a.shape[0]
        y = y + a.reshape(B, 1, -1) @ sp["attn"]["wo"].astype(y.dtype)
        h2 = L.norm(y, sp["ln2"], cfg.norm)
        y = y + L.mlp(h2, sp["mlp"], cfg.act)
        return y, (hs, cxs, cBs, cCs, kc, vc)

    x, (hs, cxs, cBs, cCs, ks, vs) = jax.lax.scan(
        group_body, x, (grouped, ssm_g, cx_g, cB_g, cC_g,
                        cache["k"], cache["v"]))
    x = L.norm(x, params["ln_f"], "rmsnorm")
    logits = lm_logits(params["embed"], x)[:, 0, :]
    flat = lambda a: a.reshape((g * e,) + a.shape[2:])
    return logits, {"ssm": flat(hs), "conv_x": flat(cxs),
                    "conv_B": flat(cBs), "conv_C": flat(cCs),
                    "k": ks, "v": vs, "pos": pos + 1}


def build(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=partial(init, cfg=cfg),
        forward=partial(forward, cfg=cfg),
        loss_fn=partial(loss_fn, cfg=cfg),
        init_cache=partial(init_cache, cfg),
        prefill=partial(prefill, cfg=cfg),
        decode_step=partial(decode_step, cfg=cfg),
    )
