"""Whisper-style encoder-decoder [arXiv:2212.04356] — whisper-small.

Transformer backbone only: the mel-spectrogram + conv feature extractor is a
STUB; the batch carries precomputed frame embeddings (B, n_frames, d). Pre-LN
layernorm + GELU, sinusoidal positions (no RoPE), MHA decoder with causal
self-attention and cross-attention to the encoder memory.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.api import Model
from repro.models.embed import embed_tokens, embedding_init, lm_logits


def _xattn_init(key, cfg: ModelConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "wq": L.dense_init(k1, (d, H * hd)),
        "wk": L.dense_init(k2, (d, cfg.n_kv_heads * hd)),
        "wv": L.dense_init(k3, (d, cfg.n_kv_heads * hd)),
        "wo": L.dense_init(k4, (H * hd, d), in_dim=H * hd),
    }


def _enc_layer_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.norm_init(cfg.d_model, cfg.norm),
        "attn": L.gqa_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
        "ln2": L.norm_init(cfg.d_model, cfg.norm),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act),
    }


def _dec_layer_init(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.norm_init(cfg.d_model, cfg.norm),
        "self_attn": L.gqa_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
        "ln_x": L.norm_init(cfg.d_model, cfg.norm),
        "cross_attn": _xattn_init(k2, cfg),
        "ln2": L.norm_init(cfg.d_model, cfg.norm),
        "mlp": L.mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.act),
    }


def init(key, cfg: ModelConfig):
    ke, kenc, kdec = jax.random.split(key, 3)
    enc_keys = jax.random.split(kenc, cfg.n_enc_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": embedding_init(ke, cfg),
        "enc_layers": jax.vmap(partial(_enc_layer_init, cfg=cfg))(enc_keys),
        "ln_enc": L.norm_init(cfg.d_model, cfg.norm),
        "dec_layers": jax.vmap(partial(_dec_layer_init, cfg=cfg))(dec_keys),
        "ln_f": L.norm_init(cfg.d_model, cfg.norm),
    }


def encode(params, frames, cfg: ModelConfig, *, remat: bool = False):
    """frames: (B, F, d) precomputed frame embeddings (stub frontend)."""
    cd = jnp.dtype(cfg.compute_dtype)
    F = frames.shape[1]
    x = frames.astype(cd) + L.sinusoidal_positions(F, cfg.d_model).astype(cd)[None]
    positions = jnp.arange(F, dtype=jnp.int32)

    def body(c, lp):
        h = L.norm(c, lp["ln1"], cfg.norm)
        q, k, v = L.gqa_project(h, lp["attn"], cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, positions, 0.0)
        a = L.attention(q, k, v, q_positions=positions, kv_positions=positions,
                        causal=False)
        B = a.shape[0]
        c = c + a.reshape(B, F, -1) @ lp["attn"]["wo"].astype(c.dtype)
        h2 = L.norm(c, lp["ln2"], cfg.norm)
        c = c + L.mlp(h2, lp["mlp"], cfg.act)
        return c, None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, params["enc_layers"])
    return L.norm(x, params["ln_enc"], cfg.norm)


def _cross_attend(x, memory_kv, lp, cfg: ModelConfig):
    """x: (B,Sq,d); memory_kv: (mk, mv) each (B,F,KV,hd)."""
    mk, mv = memory_kv
    B, Sq, _ = x.shape
    h = L.norm(x, lp["ln_x"], cfg.norm)
    q = (h @ lp["cross_attn"]["wq"].astype(h.dtype)).reshape(
        B, Sq, cfg.n_heads, cfg.head_dim)
    F = mk.shape[1]
    a = L.attention(q, mk, mv,
                    q_positions=jnp.zeros((Sq,), jnp.int32),
                    kv_positions=jnp.arange(F, dtype=jnp.int32),
                    causal=False)
    return x + a.reshape(B, Sq, -1) @ lp["cross_attn"]["wo"].astype(x.dtype)


def _memory_kv(memory, lp, cfg: ModelConfig):
    B, F, _ = memory.shape
    mk = (memory @ lp["cross_attn"]["wk"].astype(memory.dtype)).reshape(
        B, F, cfg.n_kv_heads, cfg.head_dim)
    mv = (memory @ lp["cross_attn"]["wv"].astype(memory.dtype)).reshape(
        B, F, cfg.n_kv_heads, cfg.head_dim)
    return mk, mv


def _dec_layer_fwd(x, lp, memory, cfg: ModelConfig, positions, *, window,
                   collect_cache):
    h = L.norm(x, lp["ln1"], cfg.norm)
    q, k, v = L.gqa_project(h, lp["self_attn"], cfg.n_heads, cfg.n_kv_heads,
                            cfg.head_dim, positions, 0.0)
    a = L.attention(q, k, v, q_positions=positions, kv_positions=positions,
                    causal=True, window=window)
    B, S = x.shape[:2]
    x = x + a.reshape(B, S, -1) @ lp["self_attn"]["wo"].astype(x.dtype)
    mkv = _memory_kv(memory, lp, cfg)
    x = _cross_attend(x, mkv, lp, cfg)
    h2 = L.norm(x, lp["ln2"], cfg.norm)
    x = x + L.mlp(h2, lp["mlp"], cfg.act)
    return x, ((k, v, mkv[0], mkv[1]) if collect_cache else None)


def forward(params, batch, cfg: ModelConfig, *, remat: bool = False,
            collect_cache: bool = False):
    """batch: {frames (B,F,d), tokens (B,S), labels (B,S)}."""
    cd = jnp.dtype(cfg.compute_dtype)
    memory = encode(params, batch["frames"], cfg, remat=remat)
    S = batch["tokens"].shape[1]
    x = embed_tokens(params["embed"], batch["tokens"], cd)
    x = x + L.sinusoidal_positions(S, cfg.d_model).astype(cd)[None]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(c, lp):
        return _dec_layer_fwd(c, lp, memory, cfg, positions,
                              window=cfg.attn_window,
                              collect_cache=collect_cache)

    fn = jax.checkpoint(body) if remat else body
    x, caches = jax.lax.scan(fn, x, params["dec_layers"])
    x = L.norm(x, params["ln_f"], cfg.norm)
    logits = lm_logits(params["embed"], x)
    return (logits, caches) if collect_cache else logits


def loss_fn(params, batch, cfg: ModelConfig, *, remat: bool = True):
    logits = forward(params, batch, cfg, remat=remat)
    return L.lm_loss(logits, batch["labels"], cfg.vocab, batch.get("mask"))


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    from repro.models.transformer import cache_len
    cd = jnp.dtype(cfg.compute_dtype)
    kv = (cfg.n_layers, batch_size, cache_len(cfg, max_len),
          cfg.n_kv_heads, cfg.head_dim)
    xkv = (cfg.n_layers, batch_size, cfg.n_audio_frames, cfg.n_kv_heads,
           cfg.head_dim)
    return {"k": jnp.zeros(kv, cd), "v": jnp.zeros(kv, cd),
            "xk": jnp.zeros(xkv, cd), "xv": jnp.zeros(xkv, cd),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(params, batch, cfg: ModelConfig, *, max_len: int = None):
    from repro.models.transformer import _fit_kv
    logits, (ks, vs, xks, xvs) = forward(params, batch, cfg, collect_cache=True)
    cache = {"k": _fit_kv(ks, cfg, max_len), "v": _fit_kv(vs, cfg, max_len),
             "xk": xks, "xv": xvs,
             "pos": jnp.asarray(batch["tokens"].shape[1], jnp.int32)}
    return logits[:, -1, :], cache


def decode_step(params, tokens, cache, cfg: ModelConfig):
    cd = jnp.dtype(cfg.compute_dtype)
    pos = cache["pos"]
    x = embed_tokens(params["embed"], tokens[:, None], cd)
    x = x + L.sinusoidal_position_at(pos, cfg.d_model).astype(cd)[None]
    max_len = cache["k"].shape[2]
    ring = cfg.attn_window > 0 and max_len <= cfg.attn_window
    if ring:
        kv_positions = L.ring_positions(pos, max_len)
        write = jnp.mod(pos, max_len)
    else:
        kv_positions = jnp.arange(max_len, dtype=jnp.int32)
        write = pos
    q_positions = pos[None]

    def body(xc, inp):
        lp, kc, vc, xk, xv = inp
        h = L.norm(xc, lp["ln1"], cfg.norm)
        q, k, v = L.gqa_project(h, lp["self_attn"], cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, q_positions, 0.0)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, write, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, write, 0, 0))
        a = L.attention(q, kc, vc, q_positions=q_positions,
                        kv_positions=kv_positions, kv_len=pos + 1,
                        causal=True, window=cfg.attn_window)
        B = a.shape[0]
        xc = xc + a.reshape(B, 1, -1) @ lp["self_attn"]["wo"].astype(xc.dtype)
        xc = _cross_attend(xc, (xk, xv), lp, cfg)
        h2 = L.norm(xc, lp["ln2"], cfg.norm)
        xc = xc + L.mlp(h2, lp["mlp"], cfg.act)
        return xc, (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, (params["dec_layers"], cache["k"],
                                         cache["v"], cache["xk"], cache["xv"]))
    x = L.norm(x, params["ln_f"], cfg.norm)
    logits = lm_logits(params["embed"], x)[:, 0, :]
    return logits, {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"],
                    "pos": pos + 1}


def build(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=partial(init, cfg=cfg),
        forward=partial(forward, cfg=cfg),
        loss_fn=partial(loss_fn, cfg=cfg),
        init_cache=partial(init_cache, cfg),
        prefill=partial(prefill, cfg=cfg),
        decode_step=partial(decode_step, cfg=cfg),
    )
