"""Chameleon-style early-fusion VLM [arXiv:2405.09818] — chameleon-34b.

Early fusion means the backbone is a plain dense decoder over a unified
text+VQ-image-token vocabulary. The VQ-VAE image tokenizer is a STUB: the
batch carries precomputed image-patch embeddings (B, n_image_tokens, d) that
replace the embeddings of the leading positions (see
``transformer._embed_batch``). Decode is identical to the dense path.
"""
from __future__ import annotations

from functools import partial

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models.api import Model


def build(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=partial(T.init, cfg=cfg),
        forward=partial(T.forward, cfg=cfg),
        loss_fn=partial(T.loss_fn, cfg=cfg),
        init_cache=partial(T.init_cache, cfg),
        prefill=partial(T.prefill, cfg=cfg),
        decode_step=partial(T.decode_step, cfg=cfg),
    )
