"""Shared neural-net layers — raw JAX, pytree params, bf16-compute/f32-param.

Everything here is a pure function over explicit param pytrees so that the DP
machinery (which clips/averages/noises *update pytrees*) composes with any
architecture in the zoo.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_dim: Optional[int] = None, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    if in_dim is None:
        in_dim = shape[0]
    std = 1.0 / math.sqrt(in_dim)
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def norm(x, p, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def norm_init(d: int, kind: str):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """Apply RoPE. x: (..., S, H, hd); positions: (..., S) int32."""
    if theta <= 0.0:
        return x
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_position_at(pos, d: int):
    """PE row for a single (traced) position. Returns (1, d) f32."""
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10_000.0, dim / d)  # (d/2,)
    pe = jnp.stack([jnp.sin(ang), jnp.cos(ang)], axis=-1).reshape(1, d)
    return pe


def sinusoidal_positions(seq_len: int, d: int):
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, dim / d)
    pe = jnp.zeros((seq_len, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))
    return pe


# ---------------------------------------------------------------------------
# attention (GQA, causal / sliding-window / bidirectional, query-chunked)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _attend_block(q, k, v, q_pos, kv_pos, kv_len, window, causal):
    """One (all-queries-in-block × all-kv) attention. q: (B,Sq,H,hd),
    k/v: (B,Skv,KV,hd). Returns (B,Sq,H,hd). Softmax in f32."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    # scores: (B, KV, G, Sq, Skv)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (1.0 / math.sqrt(hd))
    valid = kv_pos[None, :] < kv_len if kv_len is not None else jnp.ones(
        (1, k.shape[1]), bool)
    valid = valid & (kv_pos[None, :] >= 0)  # ring-buffer slots can be empty
    if causal:
        valid = valid & (kv_pos[None, :] <= q_pos[:, None])
    if window and window > 0:
        valid = valid & (kv_pos[None, :] > q_pos[:, None] - window)
    scores = jnp.where(valid[None, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, hd)


def attention(q, k, v, *, q_positions, kv_positions, kv_len=None,
              causal=True, window: int = 0, q_chunk: int = 1024):
    """GQA attention, chunked over queries to bound the score transient.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd).
    q_positions: (Sq,), kv_positions: (Skv,) absolute positions.
    kv_len: scalar count of valid cache entries (None = all valid).
    """
    B, Sq, H, hd = q.shape
    if Sq <= q_chunk:
        return _attend_block(q, k, v, q_positions, kv_positions, kv_len,
                             window, causal)
    pad = (-Sq) % q_chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pad))
        out = attention(q, k, v, q_positions=q_positions,
                        kv_positions=kv_positions, kv_len=kv_len,
                        causal=causal, window=window, q_chunk=q_chunk)
        return out[:, :Sq]
    n = Sq // q_chunk
    qs = q.reshape(B, n, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    ps = q_positions.reshape(n, q_chunk)

    def body(_, qp):
        qc, pc = qp
        out = _attend_block(qc, k, v, pc, kv_positions, kv_len, window, causal)
        return None, out

    # remat per chunk: the backward pass recomputes one chunk's scores at a
    # time instead of saving (q_chunk × Skv) softmax residuals per chunk.
    _, outs = jax.lax.scan(jax.checkpoint(body), None, (qs, ps))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


def gqa_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d_model, n_heads * head_dim)),
        "wk": dense_init(k2, (d_model, n_kv * head_dim)),
        "wv": dense_init(k3, (d_model, n_kv * head_dim)),
        "wo": dense_init(k4, (n_heads * head_dim, d_model), in_dim=n_heads * head_dim),
    }


def gqa_project(x, p, n_heads: int, n_kv: int, head_dim: int, positions, theta):
    """x: (B,S,d) → q (B,S,H,hd), k,v (B,S,KV,hd), RoPE applied."""
    B, S, _ = x.shape
    cd = x.dtype
    q = (x @ p["wq"].astype(cd)).reshape(B, S, n_heads, head_dim)
    k = (x @ p["wk"].astype(cd)).reshape(B, S, n_kv, head_dim)
    v = (x @ p["wv"].astype(cd)).reshape(B, S, n_kv, head_dim)
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    return q, k, v


# ---------------------------------------------------------------------------
# sharding hints (no-ops outside a mesh context)
# ---------------------------------------------------------------------------


def shard_hint(x, *spec):
    """with_sharding_constraint that only applies when a mesh is in scope and
    every named axis exists + divides — so model code can annotate hot
    activations (MoE dispatch, per-client grads) without coupling tests or
    CPU runs to a mesh."""
    from repro.utils.compat import get_abstract_mesh
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    names = dict(mesh.shape_tuple)

    # drop axis names that don't exist / don't divide (entry-wise fallback)
    def fit(entry, dim):
        if entry is None:
            return None
        axes = entry if isinstance(entry, tuple) else (entry,)
        axes = tuple(a for a in axes if a in names)
        while axes:
            par = 1
            for a in axes:
                par *= names[a]
            if dim % par == 0:
                return axes if len(axes) > 1 else axes[0]
            axes = axes[1:]
        return None

    if len(spec) != x.ndim:
        return x
    fitted = [fit(e, d) for e, d in zip(spec, x.shape)]
    from jax.sharding import PartitionSpec
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*fitted))


# ---------------------------------------------------------------------------
# sliding-window ring-buffer KV cache helpers
# ---------------------------------------------------------------------------
# For window-attention decode the cache is a ring of W = attn_window slots:
# position p lives in slot p % W, so a 500k-token context needs only W slots
# (0.8% of the bytes at W=4096). Slot→position recovery is arithmetic.


def ring_positions(pos, W: int):
    """Absolute position held by each of the W ring slots at decode step
    ``pos`` (the new token's position). Negative ⇒ slot still empty."""
    i = jnp.arange(W, dtype=jnp.int32)
    return pos - jnp.mod(pos - i, W)


def ring_pack(kv, W: int, axis: int = 2):
    """Pack the last W positions of a (..., S, ...) prefill KV stack into
    ring order (slot = position % W)."""
    S = kv.shape[axis]
    if S <= W:
        return kv
    sliced = jax.lax.slice_in_dim(kv, S - W, S, axis=axis)
    return jnp.roll(sliced, S % W, axis=axis)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_init(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff)),
        "w_up": dense_init(k2, (d_model, d_ff)),
        "w_down": dense_init(k3, (d_ff, d_model), in_dim=d_ff),
    }


def swiglu(x, p):
    cd = x.dtype
    g = jax.nn.silu(x @ p["w_gate"].astype(cd))
    u = x @ p["w_up"].astype(cd)
    return (g * u) @ p["w_down"].astype(cd)


def gelu_mlp_init(key, d_model: int, d_ff: int):
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_in": dense_init(k1, (d_model, d_ff)),
        "b_in": jnp.zeros((d_ff,), jnp.float32),
        "w_out": dense_init(k2, (d_ff, d_model), in_dim=d_ff),
        "b_out": jnp.zeros((d_model,), jnp.float32),
    }


def gelu_mlp(x, p):
    cd = x.dtype
    h = jax.nn.gelu(x @ p["w_in"].astype(cd) + p["b_in"].astype(cd))
    return h @ p["w_out"].astype(cd) + p["b_out"].astype(cd)


def mlp_init(key, d_model: int, d_ff: int, act: str):
    return swiglu_init(key, d_model, d_ff) if act == "swiglu" else gelu_mlp_init(key, d_model, d_ff)


def mlp(x, p, act: str):
    return swiglu(x, p) if act == "swiglu" else gelu_mlp(x, p)


# ---------------------------------------------------------------------------
# vocab padding + loss
# ---------------------------------------------------------------------------


def pad_vocab(vocab: int, multiple: int = 256) -> int:
    return ((vocab + multiple - 1) // multiple) * multiple


def lm_loss(logits, labels, vocab: int, mask=None):
    """Cross-entropy over a (possibly padded) vocab axis. logits: (B,S,Vpad) —
    may be sharded on Vpad; everything here is elementwise or a reduction over
    that axis, so it lowers to partial reductions + a small psum under GSPMD.
    labels: (B,S) int32. mask: (B,S) float or None."""
    Vpad = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    if Vpad > vocab:
        pad_mask = jax.lax.broadcasted_iota(jnp.int32, (Vpad,), 0) >= vocab
        lf = jnp.where(pad_mask[None, None, :], NEG_INF, lf)
    lse = jax.nn.logsumexp(lf, axis=-1)
    # one-hot contraction instead of take_along_axis: sharded-vocab friendly
    # (elementwise select + reduction over the vocab axis → partial sums +
    # psum under GSPMD). Written as a fused where-reduce rather than
    # materializing the one-hot and multiplying — bit-identical (the sum
    # has exactly one nonzero term either way), one less (B,S,Vpad) pass.
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, (Vpad,), 0)
    true_logit = jnp.sum(
        jnp.where(labels[..., None] == vocab_ids, lf, 0.0), axis=-1)
    nll = lse - true_logit
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
