"""Mamba-2 / SSD (state-space duality) blocks [arXiv:2405.21060] — mamba2-370m.

The training/prefill path uses the *chunked SSD algorithm*: within a chunk the
dual quadratic (attention-like) form runs on the MXU; across chunks a scalar
decay recurrence carries the (H, p, N) state. The decode path is the O(1)
recurrence. State is kept in f32.

Layout: d_inner = expand·d_model; H = ssm_heads, p = head_dim, N = ssm_state;
single B/C group (ngroups=1) as in the Mamba-2 defaults.

Sharding note: the input projection is stored as *separate* z/x/B/C/dt
matrices (not one packed matrix) so tensor-parallel sharding of the d_inner
dimension never cuts across segments; the depthwise conv is likewise split
per segment. This is a TPU/GSPMD adaptation recorded in DESIGN.md.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.api import Model
from repro.models.embed import embed_tokens, embedding_init, lm_logits

CHUNK = 128


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def mixer_init(key, cfg: ModelConfig):
    di, N, H = d_inner(cfg), cfg.ssm_state, cfg.ssm_heads
    W = cfg.ssm_conv_width
    ks = jax.random.split(key, 8)
    dt = jnp.exp(jax.random.uniform(ks[0], (H,), jnp.float32)
                 * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "w_z": L.dense_init(ks[1], (cfg.d_model, di)),
        "w_x": L.dense_init(ks[2], (cfg.d_model, di)),
        "w_B": L.dense_init(ks[3], (cfg.d_model, N)),
        "w_C": L.dense_init(ks[4], (cfg.d_model, N)),
        "w_dt": L.dense_init(ks[5], (cfg.d_model, H)),
        "conv_x": L.dense_init(ks[6], (W, di), in_dim=W),
        "conv_B": L.dense_init(ks[7], (W, N), in_dim=W),
        "conv_C": L.dense_init(jax.random.fold_in(key, 9), (W, N), in_dim=W),
        "conv_b_x": jnp.zeros((di,), jnp.float32),
        "conv_b_B": jnp.zeros((N,), jnp.float32),
        "conv_b_C": jnp.zeros((N,), jnp.float32),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "dt_bias": dt_bias,
        "D": jnp.ones((H,), jnp.float32),
        "norm": L.norm_init(di, "rmsnorm"),
        "w_out": L.dense_init(jax.random.fold_in(key, 7), (di, cfg.d_model),
                              in_dim=di),
    }


def _causal_conv(seq, w, b):
    """Depthwise causal conv via shifted adds. seq: (B,S,C); w: (W,C)."""
    W = w.shape[0]
    out = seq * w[W - 1][None, None, :]
    for i in range(W - 1):
        shift = W - 1 - i
        shifted = jnp.pad(seq, ((0, 0), (shift, 0), (0, 0)))[:, :-shift, :]
        out = out + shifted * w[i][None, None, :]
    return jax.nn.silu(out + b[None, None, :].astype(seq.dtype))


def _proj(x, p, cfg: ModelConfig):
    """x: (B,S,d) → z (B,S,di), x_raw (B,S,di), B_raw, C_raw (B,S,N),
    dt (B,S,H) post-softplus."""
    cd = x.dtype
    z = x @ p["w_z"].astype(cd)
    x_raw = x @ p["w_x"].astype(cd)
    B_raw = x @ p["w_B"].astype(cd)
    C_raw = x @ p["w_C"].astype(cd)
    dt = jax.nn.softplus((x @ p["w_dt"].astype(cd)).astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    return z, x_raw, B_raw, C_raw, dt


def ssd_chunked(xh, dt, Bc, Cc, A, h0):
    """Chunked SSD scan (pure-jnp; the Pallas kernel mirrors this math).

    xh: (B,S,H,p); dt: (B,S,H) f32; Bc, Cc: (B,S,N); A: (H,) (negative);
    h0: (B,H,p,N) f32 initial state. Returns y (B,S,H,p) f32, h_final.
    """
    Bsz, S, H, p = xh.shape
    N = Bc.shape[-1]
    Q = min(CHUNK, S)
    assert S % Q == 0, (S, Q)
    n = S // Q
    f32 = lambda v: v.astype(jnp.float32)

    def chunk_body(h, inp):
        xc, dtc, bc, cc = inp  # (B,Q,H,p), (B,Q,H) f32, (B,Q,N), (B,Q,N)
        a = dtc * A[None, None, :]                     # (B,Q,H), negative
        cum = jnp.cumsum(a, axis=1)                    # (B,Q,H)
        seg = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Q,Q,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        Lmat = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        scores = jnp.einsum("bqn,bsn->bqs", f32(cc), f32(bc))
        w = scores[:, :, :, None] * Lmat * dtc[:, None, :, :]
        y_intra = jnp.einsum("bqsh,bshp->bqhp", w, f32(xc))
        y_inter = jnp.exp(cum)[..., None] * jnp.einsum(
            "bqn,bhpn->bqhp", f32(cc), h)
        decay_out = jnp.exp(cum[:, -1:, :] - cum)      # (B,Q,H)
        dB = (dtc * decay_out)[..., None] * f32(bc)[:, :, None, :]
        h_new = jnp.exp(cum[:, -1, :])[:, :, None, None] * h + jnp.einsum(
            "bqhn,bqhp->bhpn", dB, f32(xc))
        return h_new, (y_intra + y_inter)

    xs = (xh.reshape(Bsz, n, Q, H, p).transpose(1, 0, 2, 3, 4),
          dt.reshape(Bsz, n, Q, H).transpose(1, 0, 2, 3),
          Bc.reshape(Bsz, n, Q, N).transpose(1, 0, 2, 3),
          Cc.reshape(Bsz, n, Q, N).transpose(1, 0, 2, 3))
    h_fin, ys = jax.lax.scan(chunk_body, h0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, p)
    return y, h_fin


def mixer_fwd(x, p, cfg: ModelConfig, *, return_state: bool = False):
    """Full-sequence mixer. x: (B,S,d)."""
    di, N, H = d_inner(cfg), cfg.ssm_state, cfg.ssm_heads
    hp = di // H
    Bsz, S, _ = x.shape
    cd = x.dtype
    z, x_raw, B_raw, C_raw, dt = _proj(x, p, cfg)
    xs = _causal_conv(x_raw, p["conv_x"].astype(cd), p["conv_b_x"])
    Bc = _causal_conv(B_raw, p["conv_B"].astype(cd), p["conv_b_B"])
    Cc = _causal_conv(C_raw, p["conv_C"].astype(cd), p["conv_b_C"])
    xh = xs.reshape(Bsz, S, H, hp)
    A = -jnp.exp(p["A_log"])
    h0 = jnp.zeros((Bsz, H, hp, N), jnp.float32)
    y, h_fin = ssd_chunked(xh, dt, Bc, Cc, A, h0)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.astype(cd).reshape(Bsz, S, di)
    y = y * jax.nn.silu(z)
    y = L.rmsnorm(y, p["norm"]["scale"])
    out = y @ p["w_out"].astype(cd)
    if return_state:
        W = cfg.ssm_conv_width
        tails = (x_raw[:, -(W - 1):, :], B_raw[:, -(W - 1):, :],
                 C_raw[:, -(W - 1):, :])
        return out, h_fin, tails
    return out


def mixer_step(x, p, cfg: ModelConfig, h, conv_state):
    """One-token recurrence. x: (B,1,d); h: (B,H,p,N) f32;
    conv_state: (cx (B,W-1,di), cB (B,W-1,N), cC (B,W-1,N)) raw history."""
    di, N, H = d_inner(cfg), cfg.ssm_state, cfg.ssm_heads
    hp = di // H
    Bsz = x.shape[0]
    cd = x.dtype
    z, x_raw, B_raw, C_raw, dt = _proj(x, p, cfg)
    cx, cB, cC = conv_state

    def conv1(hist, new, w, b):
        full = jnp.concatenate([hist.astype(cd), new], axis=1)  # (B,W,C)
        out = jnp.einsum("bwc,wc->bc", full, w.astype(cd)) + b.astype(cd)
        return jax.nn.silu(out), full[:, 1:, :]

    xs, cx_new = conv1(cx, x_raw, p["conv_x"], p["conv_b_x"])
    Bc, cB_new = conv1(cB, B_raw, p["conv_B"], p["conv_b_B"])
    Cc, cC_new = conv1(cC, C_raw, p["conv_C"], p["conv_b_C"])
    xh = xs.reshape(Bsz, H, hp)
    A = -jnp.exp(p["A_log"])
    dts = dt[:, 0, :]                                  # (B,H)
    decay = jnp.exp(dts * A[None, :])
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dts, Bc.astype(jnp.float32),
                     xh.astype(jnp.float32))
    h_new = decay[:, :, None, None] * h + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cc.astype(jnp.float32), h_new)
    y = y + p["D"][None, :, None] * xh.astype(jnp.float32)
    y = y.astype(cd).reshape(Bsz, 1, di)
    y = y * jax.nn.silu(z)
    y = L.rmsnorm(y, p["norm"]["scale"])
    out = y @ p["w_out"].astype(cd)
    return out, h_new, (cx_new, cB_new, cC_new)


def _layer_init(key, cfg: ModelConfig):
    return {"ln": L.norm_init(cfg.d_model, "rmsnorm"),
            "mixer": mixer_init(key, cfg)}


def init(key, cfg: ModelConfig):
    ke, kl = jax.random.split(key)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    return {
        "embed": embedding_init(ke, cfg),
        "layers": jax.vmap(partial(_layer_init, cfg=cfg))(layer_keys),
        "ln_f": L.norm_init(cfg.d_model, "rmsnorm"),
    }


def forward(params, batch, cfg: ModelConfig, *, remat: bool = False,
            collect_cache: bool = False):
    cd = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], batch["tokens"], cd)

    def body(carry, lp):
        h = L.norm(carry, lp["ln"], "rmsnorm")
        if collect_cache:
            out, h_fin, tails = mixer_fwd(h, lp["mixer"], cfg,
                                          return_state=True)
            return carry + out, (h_fin, tails)
        return carry + mixer_fwd(h, lp["mixer"], cfg), None

    fn = jax.checkpoint(body) if remat else body
    x, caches = jax.lax.scan(fn, x, params["layers"])
    x = L.norm(x, params["ln_f"], "rmsnorm")
    logits = lm_logits(params["embed"], x)
    return (logits, caches) if collect_cache else logits


def loss_fn(params, batch, cfg: ModelConfig, *, remat: bool = True):
    logits = forward(params, batch, cfg, remat=remat)
    return L.lm_loss(logits, batch["labels"], cfg.vocab, batch.get("mask"))


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    di, N, H = d_inner(cfg), cfg.ssm_state, cfg.ssm_heads
    hp = di // H
    W = cfg.ssm_conv_width
    cd = jnp.dtype(cfg.compute_dtype)
    Lr = cfg.n_layers
    return {
        "ssm": jnp.zeros((Lr, batch_size, H, hp, N), jnp.float32),
        "conv_x": jnp.zeros((Lr, batch_size, W - 1, di), cd),
        "conv_B": jnp.zeros((Lr, batch_size, W - 1, N), cd),
        "conv_C": jnp.zeros((Lr, batch_size, W - 1, N), cd),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, batch, cfg: ModelConfig, *, max_len: int = None):
    del max_len  # stateful cache — no KV to pad
    logits, (h_fins, tails) = forward(params, batch, cfg, collect_cache=True)
    cd = jnp.dtype(cfg.compute_dtype)
    cx, cB, cC = tails
    cache = {"ssm": h_fins, "conv_x": cx.astype(cd), "conv_B": cB.astype(cd),
             "conv_C": cC.astype(cd),
             "pos": jnp.asarray(batch["tokens"].shape[1], jnp.int32)}
    return logits[:, -1, :], cache


def decode_step(params, tokens, cache, cfg: ModelConfig):
    cd = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens[:, None], cd)

    def body(xc, lp_and_cache):
        lp, h, cx, cB, cC = lp_and_cache
        hin = L.norm(xc, lp["ln"], "rmsnorm")
        out, h_new, (cxn, cBn, cCn) = mixer_step(hin, lp["mixer"], cfg, h,
                                                 (cx, cB, cC))
        return xc + out, (h_new, cxn.astype(cd), cBn.astype(cd),
                          cCn.astype(cd))

    x, (hs, cxs, cBs, cCs) = jax.lax.scan(
        body, x, (params["layers"], cache["ssm"], cache["conv_x"],
                  cache["conv_B"], cache["conv_C"]))
    x = L.norm(x, params["ln_f"], "rmsnorm")
    logits = lm_logits(params["embed"], x)[:, 0, :]
    return logits, {"ssm": hs, "conv_x": cxs, "conv_B": cBs, "conv_C": cCs,
                    "pos": cache["pos"] + 1}


def build(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=partial(init, cfg=cfg),
        forward=partial(forward, cfg=cfg),
        loss_fn=partial(loss_fn, cfg=cfg),
        init_cache=partial(init_cache, cfg),
        prefill=partial(prefill, cfg=cfg),
        decode_step=partial(decode_step, cfg=cfg),
    )
