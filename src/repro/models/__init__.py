from repro.models.api import Model
from repro.models.registry import build

__all__ = ["Model", "build"]
