"""Mixture-of-Experts decoder (olmoe-1b-7b, granite-moe-3b-a800m).

GShard/Switch-style dense dispatch: top-k routing with capacity, one-hot
dispatch/combine einsums (lowering-friendly, expert-parallel over the mesh
``model`` axis when n_experts divides it). Router load-balance aux loss per
Switch Transformer. The attention blocks are shared with the dense backbone.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.api import Model
from repro.models.embed import embed_tokens, embedding_init, lm_logits
from repro.models.transformer import _attn_block

AUX_LOSS_COEF = 0.01
CAPACITY_FACTOR = 1.25


INFERENCE_CAPACITY_FACTOR = 1.5


def _capacity(n_tokens: int, n_experts: int, top_k: int,
              factor: float = CAPACITY_FACTOR) -> int:
    c = int(n_tokens * top_k * factor / n_experts) + 1
    return max(4, min(n_tokens, ((c + 15) // 16) * 16))


def router_init(key, cfg: ModelConfig):
    return {"w": L.dense_init(key, (cfg.d_model, cfg.n_experts))}


def moe_ffn_init(key, cfg: ModelConfig):
    k0, k1, k2, k3 = jax.random.split(key, 4)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.expert_d_ff
    return {
        "router": router_init(k0, cfg),
        "w_gate": jax.vmap(lambda k: L.dense_init(k, (d, f)))(jax.random.split(k1, E)),
        "w_up": jax.vmap(lambda k: L.dense_init(k, (d, f)))(jax.random.split(k2, E)),
        "w_down": jax.vmap(lambda k: L.dense_init(k, (f, d), in_dim=f))(jax.random.split(k3, E)),
    }


def route(x_flat, p, cfg: ModelConfig, capacity: int = None):
    """x_flat: (T, d). Returns combine (T,E,C) f32, dispatch (T,E,C) bool-ish,
    aux load-balance loss (scalar f32)."""
    T = x_flat.shape[0]
    E, k = cfg.n_experts, cfg.top_k
    C = capacity or _capacity(T, E, k)
    logits = (x_flat.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)          # (T, E)
    topv, topi = jax.lax.top_k(probs, k)             # (T, k)
    topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e f_e * P_e  (f = token fraction, P = mean prob)
    sel_onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)        # (T,k,E)
    frac = jnp.mean(jnp.sum(sel_onehot, axis=1), axis=0)            # (E,)
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0)) / k

    combine = jnp.zeros((T, E, C), jnp.float32)
    # running per-expert fill count across the k slots
    fill = jnp.zeros((E,), jnp.int32)
    for slot in range(k):
        e_idx = topi[:, slot]                                    # (T,)
        oh = jax.nn.one_hot(e_idx, E, dtype=jnp.int32)           # (T,E)
        pos = jnp.cumsum(oh, axis=0) - 1 + fill[None, :]         # (T,E) position in expert
        fill = fill + jnp.sum(oh, axis=0)
        pos_tok = jnp.sum(pos * oh, axis=1)                      # (T,) this slot's slot-index
        keep = (pos_tok < C)
        w = topv[:, slot] * keep.astype(jnp.float32)             # (T,)
        cap_oh = jax.nn.one_hot(jnp.where(keep, pos_tok, 0), C, dtype=jnp.float32)
        combine = combine + (w[:, None, None]
                             * oh.astype(jnp.float32)[:, :, None]
                             * cap_oh[:, None, :])
    return combine, aux


MOE_GROUP = 512  # GShard-style local routing groups


def moe_ffn(x, p, cfg: ModelConfig, *, dropless: bool = False):
    """x: (B, S, d) → (B, S, d), aux loss.

    Tokens are routed within fixed-size *local groups* (GShard §3.2): the
    dense one-hot dispatch/combine einsums are O(T·E·C) with C ∝ T/E, i.e.
    quadratic in the routed group — routing the full global batch as one
    group makes 32k-token prefills intractable (the dry-run flagged ~TB-scale
    dispatch traffic before this change). Per-group capacity bounds the
    dispatch tensors to (G, group, E, C≈group·k/E) — linear in T overall.

    ``dropless=True`` (the inference path) sets capacity = group size so no
    token is ever dropped — capacity dropping is a training-time load-balance
    mechanism; serving must not silently drop tokens, and dropping would also
    make decode inconsistent with teacher-forced scoring."""
    B, S, d = x.shape
    cd = x.dtype
    T = B * S
    group = min(MOE_GROUP, T)
    pad = (-T) % group
    xf = x.reshape(T, d)
    if pad:
        xf = jnp.concatenate([xf, jnp.zeros((pad, d), xf.dtype)], axis=0)
    G = xf.shape[0] // group
    xg = xf.reshape(G, group, d)
    # Inference: generous capacity (cf=1.5) — fully-dropless (C=T) inflates
    # the dispatch tensors E/k-fold, which the dry-run showed is 50 GiB/chip
    # at 32k-token prefill. True dropless only when the batch is tiny
    # (decode), where C=T is cheap and keeps decode == teacher-forced.
    if dropless:
        cap = group if group <= 128 else _capacity(
            group, cfg.n_experts, cfg.top_k, INFERENCE_CAPACITY_FACTOR)
    else:
        cap = None
    combine, aux = jax.vmap(lambda xr: route(xr, p, cfg, capacity=cap))(xg)
    # HILLCLIMB(moe-dispatch-shard): keep dispatch/combine group-sharded over
    # the batch axes and expert-sharded where E divides — without this, GSPMD
    # replicated the (G,t,E,C) tensors at 32k-token prefill (50 GiB/chip
    # observed in the dry-run memory analysis; ~3 GiB after).
    combine = L.shard_hint(combine.astype(jnp.bfloat16),
                           ("pod", "data"), None, "model", None)
    dispatch = (combine > 0).astype(cd)                          # (G,t,E,C)
    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg)              # (G,E,C,d)
    xe = L.shard_hint(xe, ("pod", "data"), "model", None, None)
    gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(cd)))
    up = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(cd))
    h = jnp.einsum("gecf,efd->gecd", gate * up, p["w_down"].astype(cd))
    h = L.shard_hint(h, ("pod", "data"), "model", None, None)
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(cd), h)
    y = y.reshape(-1, d)
    if pad:
        y = y[:T]
    return y.reshape(B, S, d), jnp.mean(aux)


def _layer_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.norm_init(cfg.d_model, cfg.norm),
        "attn": L.gqa_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
        "ln2": L.norm_init(cfg.d_model, cfg.norm),
        "moe": moe_ffn_init(k2, cfg),
    }


def init(key, cfg: ModelConfig):
    ke, kl = jax.random.split(key)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    return {
        "embed": embedding_init(ke, cfg),
        "layers": jax.vmap(partial(_layer_init, cfg=cfg))(layer_keys),
        "ln_f": L.norm_init(cfg.d_model, cfg.norm),
    }


def forward(params, batch, cfg: ModelConfig, *, remat: bool = False,
            collect_cache: bool = False, with_aux: bool = False,
            dropless: bool = False):
    cd = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], batch["tokens"], cd)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(carry, lp):
        xc, aux_acc = carry
        xc, kv = _attn_block(xc, lp, cfg, positions, window=cfg.attn_window)
        h = L.norm(xc, lp["ln2"], cfg.norm)
        y, aux = moe_ffn(h, lp["moe"], cfg, dropless=dropless)
        return (xc + y, aux_acc + aux), kv if collect_cache else None

    fn = jax.checkpoint(body) if remat else body
    (x, aux_total), caches = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                                          params["layers"])
    x = L.norm(x, params["ln_f"], cfg.norm)
    logits = lm_logits(params["embed"], x)
    aux_total = aux_total / cfg.n_layers
    if collect_cache:
        return logits, caches, aux_total
    return (logits, aux_total) if with_aux else logits


def loss_fn(params, batch, cfg: ModelConfig, *, remat: bool = True):
    logits, aux = forward(params, batch, cfg, remat=remat, with_aux=True)
    nll = L.lm_loss(logits, batch["labels"], cfg.vocab, batch.get("mask"))
    return nll + AUX_LOSS_COEF * aux


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    from repro.models.transformer import cache_len
    shape = (cfg.n_layers, batch_size, cache_len(cfg, max_len),
             cfg.n_kv_heads, cfg.head_dim)
    cd = jnp.dtype(cfg.compute_dtype)
    return {"k": jnp.zeros(shape, cd), "v": jnp.zeros(shape, cd),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(params, batch, cfg: ModelConfig, *, max_len: int = None):
    from repro.models.transformer import _fit_kv
    logits, (ks, vs), _ = forward(params, batch, cfg, collect_cache=True,
                                  dropless=True)
    cache = {"k": _fit_kv(ks, cfg, max_len), "v": _fit_kv(vs, cfg, max_len),
             "pos": jnp.asarray(batch["tokens"].shape[1], jnp.int32)}
    return logits[:, -1, :], cache


def decode_step(params, tokens, cache, cfg: ModelConfig):
    cd = jnp.dtype(cfg.compute_dtype)
    pos = cache["pos"]
    x = embed_tokens(params["embed"], tokens[:, None], cd)
    max_len = cache["k"].shape[2]
    ring = cfg.attn_window > 0 and max_len <= cfg.attn_window
    if ring:
        kv_positions = L.ring_positions(pos, max_len)
        write = jnp.mod(pos, max_len)
    else:
        kv_positions = jnp.arange(max_len, dtype=jnp.int32)
        write = pos
    q_positions = pos[None]

    def body(xc, lp_and_cache):
        lp, kc, vc = lp_and_cache
        h = L.norm(xc, lp["ln1"], cfg.norm)
        q, k, v = L.gqa_project(h, lp["attn"], cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, q_positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, write, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, write, 0, 0))
        a = L.attention(q, kc, vc, q_positions=q_positions,
                        kv_positions=kv_positions, kv_len=pos + 1,
                        causal=True, window=cfg.attn_window)
        B = a.shape[0]
        a = a.reshape(B, 1, cfg.n_heads * cfg.head_dim)
        xc = xc + a @ lp["attn"]["wo"].astype(xc.dtype)
        h2 = L.norm(xc, lp["ln2"], cfg.norm)
        y, _ = moe_ffn(h2, lp["moe"], cfg, dropless=True)
        return xc + y, (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.norm(x, params["ln_f"], cfg.norm)
    logits = lm_logits(params["embed"], x)[:, 0, :]
    return logits, {"k": ks, "v": vs, "pos": pos + 1}


def build(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=partial(init, cfg=cfg),
        forward=partial(forward, cfg=cfg),
        loss_fn=partial(loss_fn, cfg=cfg),
        init_cache=partial(init_cache, cfg),
        prefill=partial(prefill, cfg=cfg),
        decode_step=partial(decode_step, cfg=cfg),
    )
