"""Dense decoder-only transformer (phi3-mini/medium, granite-3-2b, stablelm-12b)
and the early-fusion VLM variant (chameleon-34b) which shares the backbone.

Layers are stacked along a leading axis and executed with ``lax.scan`` so a
48-layer model compiles one layer body; remat wraps the body for training.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.api import Model
from repro.models.embed import embed_tokens, embedding_init, lm_logits


def _layer_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.norm_init(cfg.d_model, cfg.norm),
        "attn": L.gqa_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
        "ln2": L.norm_init(cfg.d_model, cfg.norm),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act),
    }


def init(key, cfg: ModelConfig):
    ke, kl, kf = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    return {
        "embed": embedding_init(ke, cfg),
        "layers": jax.vmap(partial(_layer_init, cfg=cfg))(layer_keys),
        "ln_f": L.norm_init(cfg.d_model, cfg.norm),
    }


def _attn_block(x, lp, cfg: ModelConfig, positions, *, window: int):
    h = L.norm(x, lp["ln1"], cfg.norm)
    q, k, v = L.gqa_project(h, lp["attn"], cfg.n_heads, cfg.n_kv_heads,
                            cfg.head_dim, positions, cfg.rope_theta)
    a = L.attention(q, k, v, q_positions=positions, kv_positions=positions,
                    causal=True, window=window)
    B, S, _, _ = a.shape
    a = a.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return x + a @ lp["attn"]["wo"].astype(x.dtype), (k, v)


def _layer_fwd(x, lp, cfg: ModelConfig, positions, *, window: int):
    x, kv = _attn_block(x, lp, cfg, positions, window=window)
    h = L.norm(x, lp["ln2"], cfg.norm)
    x = x + L.mlp(h, lp["mlp"], cfg.act)
    return x, kv


def _embed_batch(params, batch, cfg: ModelConfig):
    """Early fusion: for the VLM, precomputed image-patch embeddings (the stub
    frontend's output) replace the embeddings of the first n_image positions."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], batch["tokens"], cd)
    if "image_embeds" in batch:
        img = batch["image_embeds"].astype(cd)
        n_img = img.shape[1]
        x = jnp.concatenate([img, x[:, n_img:, :]], axis=1)
    return x


def forward(params, batch, cfg: ModelConfig, *, remat: bool = False,
            collect_cache: bool = False):
    x = _embed_batch(params, batch, cfg)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(carry, lp):
        y, kv = _layer_fwd(carry, lp, cfg, positions, window=cfg.attn_window)
        return y, kv if collect_cache else None

    fn = jax.checkpoint(body) if remat else body
    x, caches = jax.lax.scan(fn, x, params["layers"])
    x = L.norm(x, params["ln_f"], cfg.norm)
    logits = lm_logits(params["embed"], x)
    return (logits, caches) if collect_cache else logits


def loss_fn(params, batch, cfg: ModelConfig, *, remat: bool = True):
    logits = forward(params, batch, cfg, remat=remat)
    return L.lm_loss(logits, batch["labels"], cfg.vocab, batch.get("mask"))


def cache_len(cfg: ModelConfig, max_len: int) -> int:
    """Window attention needs only a ring of attn_window slots."""
    if cfg.attn_window > 0:
        return min(max_len, cfg.attn_window)
    return max_len


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    shape = (cfg.n_layers, batch_size, cache_len(cfg, max_len),
             cfg.n_kv_heads, cfg.head_dim)
    cd = jnp.dtype(cfg.compute_dtype)
    return {
        "k": jnp.zeros(shape, cd),
        "v": jnp.zeros(shape, cd),
        "pos": jnp.zeros((), jnp.int32),
    }


def _pad_kv(a, max_len):
    S = a.shape[2]
    if max_len is None or max_len <= S:
        return a
    return jnp.pad(a, ((0, 0), (0, 0), (0, max_len - S), (0, 0), (0, 0)))


def _fit_kv(a, cfg: ModelConfig, max_len):
    """Fit prefill KV into the decode cache: ring-pack for window attention,
    zero-pad when the cache is longer than the prompt."""
    if cfg.attn_window > 0:
        alloc = cache_len(cfg, max(max_len or 0, a.shape[2]))
        return _pad_kv(L.ring_pack(a, alloc), alloc)
    return _pad_kv(a, max_len)


def prefill(params, batch, cfg: ModelConfig, *, max_len: int = None):
    logits, (ks, vs) = forward(params, batch, cfg, collect_cache=True)
    cache = {"k": _fit_kv(ks, cfg, max_len), "v": _fit_kv(vs, cfg, max_len),
             "pos": jnp.asarray(batch["tokens"].shape[1], jnp.int32)}
    return logits[:, -1, :], cache


def decode_step(params, tokens, cache, cfg: ModelConfig, *,
                unroll: bool = True):
    """One decode step. tokens: (B,) int32; cache from init_cache/prefill.

    HILLCLIMB(decode-unroll): the layer loop is UNROLLED by default with
    per-layer in-place cache updates. With a ``lax.scan`` over
    (layer, cache-slice) the cache travels as scan xs AND ys, so XLA
    double-buffers the full multi-GiB KV cache; unrolled, the donated cache
    is updated in place (before/after in EXPERIMENTS.md §Perf)."""
    cd = jnp.dtype(cfg.compute_dtype)
    pos = cache["pos"]
    x = embed_tokens(params["embed"], tokens[:, None], cd)  # (B,1,d)
    max_len = cache["k"].shape[2]
    ring = cfg.attn_window > 0 and max_len <= cfg.attn_window
    if ring:
        kv_positions = L.ring_positions(pos, max_len)
        write = jnp.mod(pos, max_len)
    else:
        kv_positions = jnp.arange(max_len, dtype=jnp.int32)
        write = pos
    q_positions = pos[None]

    def body(xc, lp, kc, vc):
        h = L.norm(xc, lp["ln1"], cfg.norm)
        q, k, v = L.gqa_project(h, lp["attn"], cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, q_positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, write, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, write, 0, 0))
        a = L.attention(q, kc, vc, q_positions=q_positions,
                        kv_positions=kv_positions, kv_len=pos + 1,
                        causal=True, window=cfg.attn_window)
        B = a.shape[0]
        a = a.reshape(B, 1, cfg.n_heads * cfg.head_dim)
        xc = xc + a @ lp["attn"]["wo"].astype(xc.dtype)
        h2 = L.norm(xc, lp["ln2"], cfg.norm)
        xc = xc + L.mlp(h2, lp["mlp"], cfg.act)
        return xc, kc, vc

    if unroll:
        ks, vs = cache["k"], cache["v"]
        for l in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[l], params["layers"])
            x, kl, vl = body(x, lp, ks[l], vs[l])
            ks = jax.lax.dynamic_update_index_in_dim(ks, kl, l, 0)
            vs = jax.lax.dynamic_update_index_in_dim(vs, vl, l, 0)
    else:
        def scan_body(carry, lp_and_cache):
            lp, kc, vc = lp_and_cache
            xc, kc, vc = body(carry, lp, kc, vc)
            return xc, (kc, vc)

        x, (ks, vs) = jax.lax.scan(
            scan_body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.norm(x, params["ln_f"], cfg.norm)
    logits = lm_logits(params["embed"], x)[:, 0, :]
    new_cache = {"k": ks, "v": vs, "pos": pos + 1}
    return logits, new_cache


def build(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=partial(init, cfg=cfg),
        forward=partial(forward, cfg=cfg),
        loss_fn=partial(loss_fn, cfg=cfg),
        init_cache=partial(init_cache, cfg),
        prefill=partial(prefill, cfg=cfg),
        decode_step=partial(decode_step, cfg=cfg),
    )
