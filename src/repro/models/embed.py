"""Token embedding / LM head with Megatron-style padded vocab.

The vocab is padded to a multiple of 256 so the vocab axis always shards
evenly over a 16-way model axis; the loss masks padded columns.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import embed_init, pad_vocab


def embedding_init(key, cfg: ModelConfig):
    vpad = pad_vocab(cfg.vocab)
    k1, k2 = jax.random.split(key)
    p = {"tok": embed_init(k1, (vpad, cfg.d_model))}
    if not cfg.tie_embeddings:
        p["head"] = embed_init(k2, (vpad, cfg.d_model))
    return p


def embed_tokens(p, tokens, compute_dtype):
    return jnp.take(p["tok"], tokens, axis=0).astype(compute_dtype)


def lm_logits(p, x):
    """x: (B, S, d) → logits (B, S, Vpad) in f32."""
    w = p.get("head", p["tok"])
    return jnp.einsum("bsd,vd->bsv", x, w.astype(x.dtype),
                      preferred_element_type=jnp.float32)
