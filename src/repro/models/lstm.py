"""The paper's production NWP model (§III-A): single-layer CIFG-LSTM [SSB14]
with tied input-embedding/output-projection, ~1.3M parameters, 10k vocab.

CIFG couples the input and forget gates (i = 1 − f), so there are three gate
matrices (f, o, g). A linear projection maps the hidden state back to the
embedding dimension so the tied embedding can produce logits.

Hot-path structure (PR 5 — the time-fused client step): the gate matrix is
split into ``w_x (d, 3h)`` and ``w_h (h, 3h)`` so the input projection for
*all* timesteps is one large ``(B·S, d) @ (d, 3h)`` GEMM hoisted out of the
time scan (it is h-independent); the scan step only does the small
``h @ w_h`` matmul plus the gate nonlinearities and state update.
``cfg.cell_path`` selects the recurrence implementation:

* ``"fused"`` — `kernels.cifg_cell.cifg_sequence` with the Pallas cell
  kernel as the per-step forward (compiled on TPU, interpreter elsewhere)
  and the time-fused custom backward (gate recompute + ``dw_h`` reduction
  batched over time outside the reverse scan);
* ``"seq"`` — the same time-fused sequence op with the pure-jnp cell as
  the per-step forward (the fast path on non-TPU backends, where the
  Pallas interpreter would run the cell per step);
* ``"ref"`` — the pre-split-style plain ``lax.scan`` over the jnp cell
  with ordinary jax autodiff through the scan — the validated reference;
* ``"auto"`` (default) — ``"fused"`` on TPU, ``"seq"`` elsewhere.

Old ``w_gates`` checkpoints load through the one-shot migration shim in
`repro.train.checkpoint`.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.cifg_cell import (cifg_cell_ref, cifg_sequence,
                                     cifg_states, cifg_step)
from repro.models import layers as L
from repro.models.api import Model
from repro.models.embed import embed_tokens, embedding_init, lm_logits

CELL_PATHS = ("auto", "fused", "seq", "ref")


def resolve_cell_path(cfg: ModelConfig) -> str:
    """``"auto"`` → compiled Pallas kernels on TPU, the time-fused jnp
    sequence elsewhere (the Pallas interpreter is a correctness surrogate,
    not a fast path — running it per scan step would dominate the client
    step on CPU)."""
    if cfg.cell_path != "auto":
        return cfg.cell_path
    return "fused" if jax.default_backend() == "tpu" else "seq"


def init(key, cfg: ModelConfig):
    ke, kx, kh, kp = jax.random.split(key, 4)
    d, h = cfg.d_model, cfg.d_ff  # embedding dim, hidden size
    return {
        "embed": embedding_init(ke, cfg),
        # split gate matrices — fan-in matches the fused (d+h, 3h) matrix
        # they replace, so init statistics are unchanged by the layout
        "w_x": L.dense_init(kx, (d, 3 * h), in_dim=d + h),
        "w_h": L.dense_init(kh, (h, 3 * h), in_dim=d + h),
        "b_gates": jnp.zeros((3 * h,), jnp.float32),
        "w_proj": L.dense_init(kp, (h, d), in_dim=h),
    }


def _input_projection(params, x, cd):
    """Hoisted input half of the gate pre-activations for *all* timesteps:
    one (B·S, d) @ (d, 3h) GEMM + bias. x: (B, S, d) → zx (B, S, 3h) f32."""
    B, S, d = x.shape
    zx = (x.reshape(B * S, d) @ params["w_x"].astype(cd)).astype(jnp.float32)
    return zx.reshape(B, S, -1) + params["b_gates"]


def _recurrence(params, zx, cfg: ModelConfig, remat: bool):
    """Run the CIFG recurrence over zx (B, S, 3h) → (hs (B, S, h) f32,
    (h_fin, c_fin)), dispatching on the resolved ``cell_path``."""
    B = zx.shape[0]
    hidden = cfg.d_ff
    h0 = jnp.zeros((B, hidden), jnp.float32)
    c0 = jnp.zeros((B, hidden), jnp.float32)
    path = resolve_cell_path(cfg)
    if path in ("fused", "seq"):
        hs, fin = cifg_sequence(zx.transpose(1, 0, 2), h0, c0,
                                params["w_h"], cell=path,
                                compute_dtype=cfg.compute_dtype, remat=remat)
        return hs.transpose(1, 0, 2), fin

    def step(carry, zx_t):
        h, c = cifg_cell_ref(zx_t, carry[0], carry[1], params["w_h"],
                             compute_dtype=cfg.compute_dtype)
        return (h, c), h

    if remat:
        step = jax.checkpoint(step)
    (h_fin, c_fin), hs = jax.lax.scan(step, (h0, c0), zx.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2), (h_fin, c_fin)


def forward(params, batch, cfg: ModelConfig, *, remat: bool = False,
            collect_cache: bool = False):
    cd = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cd)  # (B,S,d)
    zx = _input_projection(params, x, cd)          # (B,S,3h) — one GEMM
    hs, (h_fin, c_fin) = _recurrence(params, zx, cfg, remat)
    hs = hs.astype(cd)                             # (B,S,hidden)
    y = hs @ params["w_proj"].astype(cd)           # (B,S,d)
    logits = lm_logits(params["embed"], y)
    if collect_cache:
        return logits, (h_fin, c_fin)
    return logits


def loss_fn(params, batch, cfg: ModelConfig, *, remat: bool = False):
    logits = forward(params, batch, cfg, remat=remat)
    return L.lm_loss(logits, batch["labels"], cfg.vocab, batch.get("mask"))


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    """Decode cache. Every leaf is *per-row* (leading dim = batch): the
    serving engine scatters/gathers individual sessions by slot index, so
    nothing in the cache may be shared across rows (`repro.serve.engine`
    validates this contract)."""
    h = cfg.d_ff
    return {"h": jnp.zeros((batch_size, h), jnp.float32),
            "c": jnp.zeros((batch_size, h), jnp.float32),
            "pos": jnp.zeros((batch_size,), jnp.int32)}


def prefill(params, batch, cfg: ModelConfig, *, max_len: int = None):
    """Prompt prefill → (last-position logits (B, V), decode cache).

    An optional ``batch["length"]`` ((B,) int32, 1 ≤ length ≤ S) marks each
    row's true prompt length inside right-padded ``tokens`` — the serving
    engine's bucket-padded admission path. The recurrence is causal and the
    hoisted input-projection GEMM is row-stable, so the state and logits
    gathered at ``length - 1`` are bit-identical to an unpadded prefill of
    exactly ``length`` tokens (tests/test_serve_engine.py pins this)."""
    del max_len  # recurrent state — nothing to pad
    if "length" not in batch:
        logits, (h, c) = forward(params, batch, cfg, collect_cache=True)
        B, S = batch["tokens"].shape
        return logits[:, -1, :], {"h": h, "c": c,
                                  "pos": jnp.full((B,), S, jnp.int32)}
    cd = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    length = jnp.asarray(batch["length"], jnp.int32)
    B = tokens.shape[0]
    x = embed_tokens(params["embed"], tokens, cd)
    zx = _input_projection(params, x, cd)
    h0 = jnp.zeros((B, cfg.d_ff), jnp.float32)
    c0 = jnp.zeros((B, cfg.d_ff), jnp.float32)
    # full (S, B, H) state stacks through the same per-step forward as
    # _recurrence ("seq"'s step IS the "ref" cell), gathered at length-1
    path = resolve_cell_path(cfg)
    hs, cs = cifg_states(zx.transpose(1, 0, 2), h0, c0, params["w_h"],
                         cell="fused" if path == "fused" else "seq",
                         compute_dtype=cfg.compute_dtype)
    rows = jnp.arange(B)
    h = hs[length - 1, rows]
    c = cs[length - 1, rows]
    y = (h.astype(cd) @ params["w_proj"].astype(cd))[:, None, :]
    logits = lm_logits(params["embed"], y)[:, 0, :]
    return logits, {"h": h, "c": c, "pos": length}


def decode_step(params, tokens, cache, cfg: ModelConfig):
    cd = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens[:, None], cd)[:, 0, :]
    zx = (x @ params["w_x"].astype(cd)).astype(jnp.float32) \
        + params["b_gates"]
    if resolve_cell_path(cfg) == "fused":
        h, c = cifg_step(zx, cache["h"], cache["c"], params["w_h"],
                         compute_dtype=cfg.compute_dtype)
    else:
        h, c = cifg_cell_ref(zx, cache["h"], cache["c"], params["w_h"],
                             compute_dtype=cfg.compute_dtype)
    y = (h.astype(cd) @ params["w_proj"].astype(cd))[:, None, :]
    logits = lm_logits(params["embed"], y)[:, 0, :]
    return logits, {"h": h, "c": c, "pos": cache["pos"] + 1}


def build(cfg: ModelConfig) -> Model:
    if cfg.cell_path not in CELL_PATHS:
        raise ValueError(f"cell_path must be one of {CELL_PATHS}, "
                         f"got {cfg.cell_path!r}")
    return Model(
        cfg=cfg,
        init=partial(init, cfg=cfg),
        forward=partial(forward, cfg=cfg),
        loss_fn=partial(loss_fn, cfg=cfg),
        init_cache=partial(init_cache, cfg),
        prefill=partial(prefill, cfg=cfg),
        decode_step=partial(decode_step, cfg=cfg),
    )
