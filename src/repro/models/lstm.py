"""The paper's production NWP model (§III-A): single-layer CIFG-LSTM [SSB14]
with tied input-embedding/output-projection, ~1.3M parameters, 10k vocab.

CIFG couples the input and forget gates (i = 1 − f), so there are three gate
matrices (f, o, g). A linear projection maps the hidden state back to the
embedding dimension so the tied embedding can produce logits.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.api import Model
from repro.models.embed import embed_tokens, embedding_init, lm_logits


def init(key, cfg: ModelConfig):
    ke, kg, kp = jax.random.split(key, 3)
    d, h = cfg.d_model, cfg.d_ff  # embedding dim, hidden size
    return {
        "embed": embedding_init(ke, cfg),
        "w_gates": L.dense_init(kg, (d + h, 3 * h), in_dim=d + h),
        "b_gates": jnp.zeros((3 * h,), jnp.float32),
        "w_proj": L.dense_init(kp, (h, d), in_dim=h),
    }


def _cell(params, x_t, h, c, hidden: int):
    """One CIFG step. x_t: (B, d); h, c: (B, hidden)."""
    cd = x_t.dtype
    z = jnp.concatenate([x_t, h.astype(cd)], axis=-1) @ params["w_gates"].astype(cd)
    z = z.astype(jnp.float32) + params["b_gates"]
    f = jax.nn.sigmoid(z[:, :hidden] + 1.0)   # forget-bias 1
    o = jax.nn.sigmoid(z[:, hidden:2 * hidden])
    g = jnp.tanh(z[:, 2 * hidden:])
    c_new = f * c + (1.0 - f) * g             # CIFG: i = 1 − f
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def forward(params, batch, cfg: ModelConfig, *, remat: bool = False,
            collect_cache: bool = False):
    cd = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    B, S = tokens.shape
    hidden = cfg.d_ff
    x = embed_tokens(params["embed"], tokens, cd)  # (B,S,d)
    h0 = jnp.zeros((B, hidden), jnp.float32)
    c0 = jnp.zeros((B, hidden), jnp.float32)

    def step(carry, x_t):
        h, c = carry
        h, c = _cell(params, x_t, h, c, hidden)
        return (h, c), h

    (h_fin, c_fin), hs = jax.lax.scan(step, (h0, c0), x.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2).astype(cd)          # (B,S,hidden)
    y = hs @ params["w_proj"].astype(cd)           # (B,S,d)
    logits = lm_logits(params["embed"], y)
    if collect_cache:
        return logits, (h_fin, c_fin)
    return logits


def loss_fn(params, batch, cfg: ModelConfig, *, remat: bool = True):
    logits = forward(params, batch, cfg)
    return L.lm_loss(logits, batch["labels"], cfg.vocab, batch.get("mask"))


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    h = cfg.d_ff
    return {"h": jnp.zeros((batch_size, h), jnp.float32),
            "c": jnp.zeros((batch_size, h), jnp.float32),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(params, batch, cfg: ModelConfig, *, max_len: int = None):
    del max_len  # recurrent state — nothing to pad
    logits, (h, c) = forward(params, batch, cfg, collect_cache=True)
    return logits[:, -1, :], {"h": h, "c": c,
                              "pos": jnp.asarray(batch["tokens"].shape[1],
                                                 jnp.int32)}


def decode_step(params, tokens, cache, cfg: ModelConfig):
    cd = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens[:, None], cd)[:, 0, :]
    h, c = _cell(params, x, cache["h"], cache["c"], cfg.d_ff)
    y = (h.astype(cd) @ params["w_proj"].astype(cd))[:, None, :]
    logits = lm_logits(params["embed"], y)[:, 0, :]
    return logits, {"h": h, "c": c, "pos": cache["pos"] + 1}


def build(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=partial(init, cfg=cfg),
        forward=partial(forward, cfg=cfg),
        loss_fn=partial(loss_fn, cfg=cfg),
        init_cache=partial(init_cache, cfg),
        prefill=partial(prefill, cfg=cfg),
        decode_step=partial(decode_step, cfg=cfg),
    )
