"""family string → model builder."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, lstm, mamba2, moe, transformer, vlm
from repro.models.api import Model

_BUILDERS = {
    "dense": transformer.build,
    "moe": moe.build,
    "ssm": mamba2.build,
    "hybrid": hybrid.build,
    "encdec": encdec.build,
    "vlm": vlm.build,
    "lstm": lstm.build,
}


def build(cfg: ModelConfig) -> Model:
    if cfg.family not in _BUILDERS:
        raise KeyError(f"unknown family {cfg.family!r}")
    return _BUILDERS[cfg.family](cfg)
