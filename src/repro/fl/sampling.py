"""Client sampling for federated rounds.

The paper's Algorithm 1 uses *fixed-size* rounds: exactly qN users sampled
without replacement — in the production system, from the (much smaller,
Pace-Steering-shaped) set of checked-in devices, which is precisely the gap
between deployed mechanism and provable guarantee discussed in §V-A.
Poisson sampling (the [MRTZ17] scheme) is provided for comparison.

These are the *host-loop* (NumPy) samplers. The device engine has two
on-device counterparts: `fl.engine.sample_cohort` / `fl.engine.
poisson_select` (the monolithic ``sampler="global"`` family) and the
mesh-sharded block-local Gumbel top-k of `fl.pop_sampler`
(``sampler="sharded"`` — fleet-scale O(N) state sharded over the cohort
mesh).
"""
from __future__ import annotations

import warnings
from typing import Optional

import numpy as np

from repro.fl.population import PopulationSim


def fixed_size_sample(rng: np.random.Generator, ids: np.ndarray, k: int,
                      weights: Optional[np.ndarray] = None, *,
                      min_size: Optional[int] = None) -> np.ndarray:
    """Sample exactly k without replacement (weighted when Pace Steering
    shapes priorities).

    An under-populated check-in pool shrinks the round below the k that
    σ = zS/qN was calibrated for — never silently: a short round warns with
    realized-vs-target, and falls below ``min_size`` (a report goal) it
    raises instead, the host-loop analogue of the engine's round abort."""
    realized = min(k, ids.shape[0])
    if min_size is not None and realized < min_size:
        raise ValueError(
            f"check-in pool supports only {realized} of the {k} requested "
            f"clients — below the report goal ({min_size}); the round must "
            "abort rather than release with σ calibrated to the full round")
    if realized < k:
        warnings.warn(
            f"check-in pool supports only {realized} of the {k} requested "
            "clients; σ = zS/qN is calibrated to the full round size",
            RuntimeWarning, stacklevel=2)
    return rng.choice(ids, size=realized, replace=False, p=weights)


def poisson_sample(rng: np.random.Generator, ids: np.ndarray,
                   q: float) -> np.ndarray:
    return ids[rng.random(ids.shape[0]) < q]


def sample_round(pop: PopulationSim, rng: np.random.Generator,
                 round_idx: int, clients_per_round: int,
                 scheme: str = "fixed",
                 min_size: Optional[int] = None) -> np.ndarray:
    """Production round sampling: check-in → Pace-Steering weights → sample.
    ``min_size`` (a report goal) makes a too-small fixed round raise instead
    of shrinking silently — see :func:`fixed_size_sample`."""
    checked = pop.checked_in(round_idx)
    if scheme == "poisson":
        chosen = poisson_sample(rng, checked,
                                clients_per_round / pop.n_users)
    else:
        w = pop.selection_weights(checked, round_idx)
        chosen = fixed_size_sample(rng, checked, clients_per_round, w,
                                   min_size=min_size)
    pop.mark_participated(chosen, round_idx)
    return chosen
