"""Client sampling for federated rounds.

The paper's Algorithm 1 uses *fixed-size* rounds: exactly qN users sampled
without replacement — in the production system, from the (much smaller,
Pace-Steering-shaped) set of checked-in devices, which is precisely the gap
between deployed mechanism and provable guarantee discussed in §V-A.
Poisson sampling (the [MRTZ17] scheme) is provided for comparison.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.fl.population import PopulationSim


def fixed_size_sample(rng: np.random.Generator, ids: np.ndarray, k: int,
                      weights: Optional[np.ndarray] = None) -> np.ndarray:
    """Sample exactly k without replacement (weighted when Pace Steering
    shapes priorities)."""
    k = min(k, ids.shape[0])
    return rng.choice(ids, size=k, replace=False, p=weights)


def poisson_sample(rng: np.random.Generator, ids: np.ndarray,
                   q: float) -> np.ndarray:
    return ids[rng.random(ids.shape[0]) < q]


def sample_round(pop: PopulationSim, rng: np.random.Generator,
                 round_idx: int, clients_per_round: int,
                 scheme: str = "fixed") -> np.ndarray:
    """Production round sampling: check-in → Pace-Steering weights → sample."""
    checked = pop.checked_in(round_idx)
    if scheme == "poisson":
        chosen = poisson_sample(rng, checked,
                                clients_per_round / pop.n_users)
    else:
        w = pop.selection_weights(checked, round_idx)
        chosen = fixed_size_sample(rng, checked, clients_per_round, w)
    pop.mark_participated(chosen, round_idx)
    return chosen
