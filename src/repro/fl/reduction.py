"""Canonical topology-invariant cohort reduction (shared by engine + host).

Because float addition is not associative, the *association* of the round's
clipped-update sum is part of the DP mechanism's contract: the sharded
engine, the unsharded engine, and the host reference loop must all combine
per-client contributions in the same fixed order or their trajectories (and
anything downstream — σ calibration checks, parity tests, the secret-sharer
measurements) drift with the execution topology.

The canonical association has two levels:

* **across blocks** — the padded cohort buffer is split into
  :data:`CANON_BLOCKS` contiguous blocks whose boundaries align with every
  supported shard boundary; block partials are combined by a fixed pairwise
  tree (:func:`fold_blocks`). Bit-identical for every shard count dividing
  :data:`CANON_BLOCKS` (PR 3).
* **across pods** — on a 2-D ``(pod, data)`` cohort layout each pod owns a
  contiguous group of canonical blocks: the group is folded *pod-locally*
  by the same pairwise tree and only the pod partials cross the inter-pod
  axis, where the same tree combines them (:func:`fold_pods`). Because
  :data:`CANON_BLOCKS` is a power of two, this two-level fold is exactly a
  re-bracketing of :func:`fold_blocks`' balanced tree — bit-identical to
  the flat fold for every pod count dividing the block count, which is
  what keeps the whole ``pods × shards`` family (every product dividing
  :data:`CANON_BLOCKS`) inside one bit-parity class (PR 6).
* **within a block** — slots are folded strictly left-to-right, one at a
  time (:func:`slot_fold` — ``(((0 + u₀) + u₁) + u₂) + …``). A streaming
  accumulator that processes the block in chunks of any size reproduces the
  identical association as long as chunks are contiguous and the per-chunk
  fold is sequential — which is exactly how `fl.client.stream_block_sums`
  consumes it. Bit-identical across every ``cohort_chunk`` dividing the
  block size (PR 4).

Masked slots contribute *exactly* zero: ``0·x ∈ {+0, −0}`` and IEEE-754
addition of a signed zero to any accumulator that is not ``−0`` is exact;
the accumulators start at ``+0`` and a round-to-nearest sum can only produce
``−0`` from ``−0`` operands, so the fold never creates one.

The same canonical block grid (:func:`canon_pad` / :func:`n_canon_blocks`)
also lays out the *population* axis under the sharded cohort sampler —
`fl.pop_sampler` re-exports the pair as ``pop_pad`` / ``n_pop_blocks``.
There the blocks carry no float association (selection is an exact
integer-keyed top-k); what they provide is the topology-independent
*block-keyed PRNG* layout, the sampler analogue of this module's
topology-independent association.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Canonical block count of the topology-invariant cohort reduction: results
# are bit-identical across every shard count dividing this. 8 covers the
# power-of-two shard counts the CI matrix exercises; a non-dividing
# num_shards still works (blocks are padded up) but is only bit-stable
# against itself.
CANON_BLOCKS = 8

# Auto-selected cohort_chunk ceiling: the streaming accumulator's peak
# update memory is O(cohort_chunk · |params|), so the default caps the
# chunk at the largest divisor of the block size ≤ this.
DEFAULT_MAX_CHUNK = 32


def block_sums(a, n_blocks: int):
    """Sum contiguous equal blocks of the leading axis → (n_blocks, ...).

    XLA-reduction association (the *materializing* path); the streaming path
    builds the same block partials with :func:`slot_fold` instead.
    """
    blk = a.shape[0] // n_blocks
    return a.reshape((n_blocks, blk) + a.shape[1:]).sum(axis=1)


def fold_blocks(a):
    """Fixed pairwise-adjacent tree combine over the leading axis."""
    while a.shape[0] > 1:
        half = a.shape[0] // 2
        c = a[0:2 * half:2] + a[1:2 * half:2]
        if a.shape[0] % 2:
            c = jnp.concatenate([c, a[-1:]], axis=0)
        a = c
    return a[0]


def fold_pods(blocks, num_pods: int = 1):
    """Two-level canonical fold over a ``(pod, data)`` cohort layout: each
    pod's contiguous group of ``blocks.shape[0] / num_pods`` block partials
    is folded pod-locally by :func:`fold_blocks`' pairwise tree, then the
    pod partials are combined by the same tree — the only values that ever
    need to cross the inter-pod axis.

    For a power-of-two block count this is exactly a re-bracketing of the
    flat :func:`fold_blocks` balanced tree (a pod partial *is* an internal
    node of it), so the result is bit-identical to ``fold_blocks(blocks)``
    for every power-of-two ``num_pods`` dividing the block count — the
    property that keeps the engine's ``pods × shards`` parity family one
    bit-exact class. Non-dividing pod counts are a layout error, not a
    padding case (block counts pad to the pod grid upstream, see
    :func:`n_canon_blocks`)."""
    if num_pods == 1:
        return fold_blocks(blocks)
    if num_pods < 1 or blocks.shape[0] % num_pods:
        raise ValueError(
            f"fold_pods: num_pods={num_pods} must divide the block count "
            f"{blocks.shape[0]} — each pod owns a contiguous group of whole "
            "canonical blocks (size the grid with n_canon_blocks(num_shards,"
            " num_pods))")
    per = blocks.shape[0] // num_pods
    partials = jnp.stack([fold_blocks(blocks[p * per:(p + 1) * per])
                          for p in range(num_pods)])
    return fold_blocks(partials)


def slot_fold(acc, stacked):
    """Strict left-to-right sequential sum of ``stacked``'s leading axis
    into ``acc`` — the canonical *intra-block* association. Splitting the
    leading axis into contiguous chunks and folding chunk-by-chunk yields
    bit-identical results for every chunk size, which is the invariant the
    streaming accumulator's ``cohort_chunk`` parity rests on."""
    def step(a, x):
        return jax.tree_util.tree_map(jnp.add, a, x), None
    acc, _ = jax.lax.scan(step, acc, stacked)
    return acc


def canon_pad(n: int, num_shards: int = 1, num_pods: int = 1) -> int:
    """Smallest padded cohort-buffer size ≥ ``n`` whose canonical blocks
    align with ``num_pods × num_shards`` shard boundaries (each of the
    ``num_pods`` pods owns a contiguous group of whole blocks, each of its
    per-pod shards a contiguous sub-group). For every topology whose total
    shard count ``num_pods · num_shards`` divides :data:`CANON_BLOCKS` the
    padded size (and hence the reduction tree) is *identical*, which is
    what makes cross-topology parity bit-exact."""
    nb = n_canon_blocks(num_shards, num_pods)
    return -(-max(int(n), 1) // nb) * nb


def n_canon_blocks(num_shards: int = 1, num_pods: int = 1) -> int:
    """Block count of the canonical reduction: :data:`CANON_BLOCKS` whenever
    the total shard count ``num_pods · num_shards`` divides it (the
    bit-parity regime); otherwise the next multiple of the total so both
    pod and shard boundaries still land on block boundaries — nobody is
    ever truncated, awkward topologies just pad further."""
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if num_pods < 1:
        raise ValueError(f"num_pods must be >= 1, got {num_pods}")
    total = num_shards * num_pods
    if CANON_BLOCKS % total == 0:
        return CANON_BLOCKS
    return total * max(1, -(-CANON_BLOCKS // total))


def auto_chunk(blk: int, max_chunk: int = DEFAULT_MAX_CHUNK) -> int:
    """Largest divisor of the block size ≤ ``max_chunk`` — the default
    ``cohort_chunk``. Dividing the block keeps chunk boundaries inside
    block boundaries, so the streaming fold reproduces the canonical
    intra-block association exactly."""
    for c in range(min(blk, max_chunk), 0, -1):
        if blk % c == 0:
            return c
    return 1


def resolve_chunk(cohort_chunk, blk: int, strict: bool = True) -> int:
    """Validate/auto-select the streaming chunk size for block size ``blk``.

    ``None`` → :func:`auto_chunk`; ``0`` → 0, the materializing-path
    sentinel (callers dispatch on it); an explicit value must divide the
    block size (that is the bit-parity regime — a straddling chunk would
    change which block a slot folds into). With ``strict=False`` a
    non-dividing value is rounded down to the largest divisor ≤ it instead
    of raising — the host loop's realized round size (and hence block size)
    varies per round, so a fixed knob can't be expected to divide every
    one."""
    if cohort_chunk is None:
        return auto_chunk(blk)
    c = int(cohort_chunk)
    if c == 0 or (c >= 1 and blk % c == 0):
        return c
    if not strict and c >= 1:
        return auto_chunk(blk, max_chunk=c)
    divisors = [d for d in range(1, blk + 1) if blk % d == 0]
    raise ValueError(
        f"cohort_chunk={cohort_chunk} must divide the canonical block "
        f"size {blk} (padded cohort / {CANON_BLOCKS} blocks) so chunk "
        f"boundaries stay inside block boundaries; valid values: "
        f"{divisors} (or None to auto-select, 0 for the materializing "
        "path)")


def cohort_sum(tree, mask, n_blocks: int = CANON_BLOCKS,
               num_pods: int = 1):
    """Topology-invariant masked sum over a stacked cohort pytree.

    ``tree`` has a leading cohort axis, ``mask`` is the (C,) 0/1 slot mask.
    Masked slots contribute *exactly* zero (0·x = 0 and x + 0 = x are exact
    in IEEE float), and the reduction runs block-local sums followed by a
    fixed pairwise tree over the blocks — per pod first, then across the
    ``num_pods`` pod partials (:func:`fold_pods`) — the same association no
    matter how the cohort axis is later sharded, so the DP sensitivity of
    the sum to any single slot is the same under every aggregation
    topology."""
    m = mask.astype(jnp.float32)
    pad = -(-m.shape[0] // n_blocks) * n_blocks - m.shape[0]

    def one(l):
        lm = l.astype(jnp.float32) * m.reshape((-1,) + (1,) * (l.ndim - 1))
        if pad:
            lm = jnp.concatenate(
                [lm, jnp.zeros((pad,) + lm.shape[1:], lm.dtype)], axis=0)
        return fold_pods(block_sums(lm, n_blocks), num_pods)

    return jax.tree_util.tree_map(one, tree)
