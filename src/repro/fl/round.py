"""Federated round orchestration: sample → local train → Algorithm 1 server.

This is the *simulation* driver (CPU-scale); the production-shape
distributed round is `repro.launch.steps.fed_train_step`. Three backends:

* ``"engine"`` (default for multi-round work) — the compiled multi-round
  engine (`repro.fl.engine.SimEngine`): population, sampling, client
  batching and the server step all live on device; K rounds per jit call.
* ``"engine_python"`` — the engine's per-round-jit reference loop (same
  PRNG stream → identical trajectories; used by parity tests).
* ``"host"`` — the original numpy-sampling, host-stacking loop. Kept as the
  independent reference implementation exercising `PopulationSim` /
  `fl.sampling` and real host data movement.

``sampling`` (default ``dp.sampling``) selects fixed-size rounds (Algorithm
1) or Poisson-composed variable-size rounds on every backend; the accountant
is constructed with the matching bound. ``cohort_chunk`` / ``clip_path``
control the streaming round accumulation on *every* backend (both the
engine and the host loop fold ``cohort_chunk`` clients at a time through
the canonical block grid instead of materializing the full clipped-update
stack; ``cohort_chunk=0`` restores the materializing reference). Engine
backends additionally accept ``num_shards`` / ``num_pods`` (shard the
per-round cohort axis across a 1-D ``(data,)`` or 2-D ``(pod, data)``
device mesh — trajectories are bit-identical across every topology whose
``num_pods × num_shards`` divides `engine.CANON_BLOCKS` *and* across
dividing chunk sizes, see `repro.fl.engine`) and an in-scan
``eval_fn(params, round_idx)`` hook, whose stacked outputs land in
``trainer.eval_history``.

Engine backends also accept ``fault_config`` (`fl.faults.FaultConfig`):
the production round fault model — over-selection, report goals, DP-safe
aborts. Under it the accountant composes only *committed* rounds (an
aborted round released nothing), round records carry
``n_selected``/``n_reported``/``n_clients``/``committed``, and
`save_run_state` / `restore_run_state` make long runs crash-survivable
(resume is bit-exact, faults on or off).

Engine backends also accept ``population_backend`` / ``population_store``
(see `repro.data.population_store`): with ``population_backend="streamed"``
the corpus stays host-resident (in RAM or an mmap store directory) and the
engine stages one cohort per round onto device — trajectories stay
bit-exact against the device-resident default. A ``population_store`` may
replace the ``dataset`` entirely (pass ``dataset=None``) for
population-scale runs where no `FederatedDataset` is ever materialized.

Engine backends also accept ``sampler="sharded"`` (`fl.pop_sampler`): the
mesh-sharded block-local Gumbel top-k cohort sampler, whose O(N) population
state and selection work shard over the same ``(pod, data)`` mesh as the
cohort — the fleet-scale companion to the streamed population backend. It
is a different (equally exact) sampler family than the default
``"global"``; mirrored host state (``trainer.participation``, Pace-Steering
recency) is sliced back to ``n_users`` transparently.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ClientConfig, DPConfig
from repro.core import accountant as acct
from repro.core.dp_fedavg import finalize_round, server_step
from repro.core.server_optim import ServerOptState, init_state
from repro.data.federated import FederatedDataset
from repro.data.population_store import as_population_store
from repro.fl.client import make_round_fn
from repro.fl.engine import EngineState, SimEngine
from repro.fl.faults import FaultConfig
from repro.fl.population import PopulationSim
from repro.fl.sampling import sample_round
from repro.models.api import Model
from repro.train import checkpoint

BACKENDS = ("host", "engine", "engine_python")


@dataclass
class TrainerState:
    params: object
    opt_state: ServerOptState
    round_idx: int = 0
    history: List[Dict] = field(default_factory=list)


class FederatedTrainer:
    """End-to-end DP-FedAvg trainer over a simulated device population."""

    def __init__(self, model: Model, dataset: Optional[FederatedDataset],
                 dp: DPConfig, client: ClientConfig,
                 pop: Optional[PopulationSim] = None, seed: int = 0,
                 n_local_batches: int = 4, backend: str = "host",
                 rounds_per_call: int = 8, sampling: Optional[str] = None,
                 num_shards: int = 1, num_pods: int = 1,
                 cohort_chunk: Optional[int] = None,
                 clip_path: str = "fused",
                 population_backend: str = "device",
                 population_store=None, sampler: str = "global",
                 fault_config: Optional[FaultConfig] = None, eval_fn=None,
                 eval_every: int = 1):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {backend!r}")
        if (num_shards != 1 or num_pods != 1) and backend == "host":
            raise ValueError("num_shards/num_pods are engine-backend "
                             "features (the host loop stacks clients on one "
                             "host); use backend='engine'")
        if backend == "host" and fault_config is not None:
            raise ValueError("fault_config is an engine-backend feature "
                             "(the over-selection/report-goal protocol lives "
                             "in the engine round bodies); use "
                             "backend='engine'")
        if backend == "host" and sampler != "global":
            raise ValueError("sampler is an engine-backend feature (the "
                             "host loop samples via PopulationSim); use "
                             "backend='engine'")
        if backend == "host" and (population_backend != "device"
                                  or population_store is not None):
            raise ValueError("population_backend/population_store are "
                             "engine-backend features (the host loop reads "
                             "the FederatedDataset directly); use "
                             "backend='engine'")
        if dataset is None and population_store is None:
            raise ValueError("pass a FederatedDataset, a population_store, "
                             "or both")
        if dataset is None and backend == "host":
            raise ValueError("the host backend needs a FederatedDataset "
                             "(population stores are engine-backend data)")
        self.model = model
        self.dataset = dataset
        self.population_store = population_store
        self.dp = dp
        self.client = client
        self.n_local_batches = n_local_batches
        self.backend = backend
        self.sampling = sampling or getattr(dp, "sampling", "fixed")
        if self.sampling not in ("fixed", "poisson"):
            raise ValueError(f"sampling must be 'fixed' or 'poisson', "
                             f"got {self.sampling!r}")
        if population_store is not None:
            store = as_population_store(population_store)
            if (dataset is not None
                    and len(dataset.users) != store.n_users):
                raise ValueError(
                    f"dataset has {len(dataset.users)} users but the "
                    f"population store holds {store.n_users} — pass matching "
                    "populations (or only one of the two)")
            self.population_store = store
            n_users = store.n_users
            synth = np.nonzero(np.asarray(store.synthetic))[0].tolist()
        else:
            n_users = len(dataset.users)
            synth = [u.user_id for u in dataset.users if u.is_synthetic]
        self.pop = pop or PopulationSim(n_users,
                                        synthetic_ids=synth, seed=seed)
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.PRNGKey(seed)
        self.accountant = acct.MomentsAccountant(
            q=dp.clients_per_round / max(n_users, 1),
            noise_multiplier=dp.noise_multiplier,
            sampling="poisson" if self.sampling == "poisson" else "wor")
        params = model.init(jax.random.PRNGKey(seed + 1))
        self.state = TrainerState(params, init_state(params))
        self.participation = np.zeros(n_users, np.int64)
        # in-scan eval hook output, accumulated across engine chunks:
        # {"round": (n,), "mask": (n,) bool, "values": stacked eval pytree}
        self.eval_history: Optional[Dict] = None

        if backend == "host":
            if eval_fn is not None:
                raise ValueError("eval_fn is an engine-backend feature "
                                 "(in-scan hook); score params post hoc on "
                                 "the host backend instead")
            # the host reference loop streams its round body through the
            # same chunked accumulator as the engine (identical canonical
            # association; see fl.client.round_compute)
            self._round_fn = make_round_fn(model, client, dp,
                                           cohort_chunk=cohort_chunk,
                                           clip_path=clip_path)
            self.engine = None
            self._estate = None
        else:
            # scalar population dynamics come from the PopulationSim config;
            # the synthetic-device mask comes from the dataset itself (the
            # engine's RNG stream is the trainer seed, not pop.seed — round
            # draws live on device)
            if sorted(self.pop.synthetic_ids) != synth:
                raise ValueError(
                    "engine backends take the synthetic-device mask from "
                    f"the dataset ({synth}), but the PopulationSim was "
                    f"built with synthetic_ids={list(self.pop.synthetic_ids)}"
                    " — make them agree (or omit synthetic_ids)")
            data = (self.population_store if self.population_store is not None
                    else dataset.to_device_arrays())
            self.engine = SimEngine(
                model, data, dp, client,
                n_local_batches=n_local_batches,
                population_backend=population_backend,
                availability=self.pop.availability,
                pace_cooldown=self.pop.pace_cooldown,
                pace_penalty=self.pop.pace_penalty,
                rounds_per_call=rounds_per_call,
                sampling=self.sampling, num_shards=num_shards,
                num_pods=num_pods, sampler=sampler,
                cohort_chunk=cohort_chunk, clip_path=clip_path,
                fault_config=fault_config,
                eval_fn=eval_fn, eval_every=eval_every)
            self._estate = self.engine.init_state(
                params, seed=seed, opt_state=self.state.opt_state)

    # ------------------------------------------------------------- host path

    def _stack_clients(self, ids: np.ndarray):
        tensors = [self.dataset.user_tensor(int(u), self.client.batch_size,
                                            self.n_local_batches, self.rng)
                   for u in ids]
        return {k: jnp.asarray(np.stack([t[k] for t in tensors]))
                for k in tensors[0]}

    def _run_round_host(self) -> Dict:
        s = self.state
        ids = sample_round(self.pop, self.rng, s.round_idx,
                           self.dp.clients_per_round, scheme=self.sampling)
        self.participation[ids] += 1
        if len(ids):
            stacked = self._stack_clients(ids)
            total, mean_norm, frac_clipped, loss = self._round_fn(s.params,
                                                                  stacked)
        else:  # an empty Poisson round still takes a (pure-noise) server step
            total = jax.tree_util.tree_map(
                lambda l: jnp.zeros_like(l, jnp.float32), s.params)
            mean_norm = frac_clipped = loss = jnp.zeros(())
        self.key, sub = jax.random.split(self.key)
        # Poisson rounds divide by the *expected* round size qN [MRTZ17] so
        # σ matches the engine and the DPConfig calibration; fixed rounds by
        # the realized (= configured) size as in Algorithm 1.
        denom = (len(ids) if self.sampling == "fixed"
                 else self.dp.clients_per_round)
        delta, stats = finalize_round(total, denom, sub, self.dp,
                                      stats=(mean_norm, frac_clipped))
        s.params, s.opt_state = server_step(s.params, s.opt_state, delta,
                                            self.dp)
        self.accountant.step()
        s.round_idx += 1
        rec = {"round": s.round_idx, "loss": float(loss),
               "mean_update_norm": float(mean_norm),
               "frac_clipped": float(frac_clipped),
               "n_clients": int(len(ids)),
               "n_target": int(self.dp.clients_per_round),
               "noise_std": float(stats.noise_std)}
        s.history.append(rec)
        return rec

    # ----------------------------------------------------------- engine path

    def _append_eval(self, rounds_arr: np.ndarray, mask: np.ndarray,
                     values) -> None:
        chunk = {"round": rounds_arr, "mask": np.asarray(mask, bool),
                 "values": values}
        if self.eval_history is None:
            self.eval_history = chunk
        else:
            self.eval_history = jax.tree_util.tree_map(
                lambda a, b: np.concatenate([a, b]), self.eval_history, chunk)

    def _train_engine(self, rounds: int, log_every: int = 0) -> List[Dict]:
        s = self.state
        runner = (self.engine.run if self.backend == "engine"
                  else self.engine.run_python)
        recs = []
        done = 0
        stepped = 0
        while done < rounds:
            # chunk by log_every so progress lines appear while training
            k = min(log_every or rounds, rounds - done)
            start = s.round_idx
            self._estate, hist = runner(self._estate, k)
            if "eval" in hist:
                self._append_eval(np.arange(start + 1, start + k + 1),
                                  hist["eval_mask"], hist["eval"])
            faulted = "committed" in hist
            # only committed rounds released anything, so only they compose
            stepped += int(np.sum(hist["committed"])) if faulted else k
            for i in range(k):
                s.round_idx += 1
                rec = {"round": s.round_idx, "loss": float(hist["loss"][i]),
                       "mean_update_norm":
                           float(hist["mean_update_norm"][i]),
                       "frac_clipped": float(hist["frac_clipped"][i]),
                       "n_clients": int(hist["n_clients"][i]),
                       "noise_std": float(hist["noise_std"][i])}
                if faulted:
                    rec["n_selected"] = int(hist["n_selected"][i])
                    rec["n_reported"] = int(hist["n_reported"][i])
                    rec["committed"] = bool(hist["committed"][i])
                s.history.append(rec)
                recs.append(rec)
                if log_every and rec["round"] % log_every == 0:
                    self._log(rec)
            done += k
        s.params = self._estate.params
        s.opt_state = self._estate.opt_state
        self.accountant.step(stepped)
        # mirror device population state back into the host PopulationSim so
        # post-hoc analyses (participation, Pace-Steering recency) see it
        # (the sharded sampler's vectors carry n_pad ≥ n_users rows — the
        # padding never participates, slice it off)
        n = self.engine.n_users
        self.participation = np.asarray(
            self._estate.participation, np.int64)[:n]
        self.pop.absorb_last_round(
            np.asarray(self._estate.last_round)[:n])
        return recs

    # ------------------------------------------------------- crash resilience

    def save_run_state(self, path) -> None:
        """Persist the full mid-run state durably (engine backends): params,
        server-optimizer state, the engine PRNG key (which *is* the sampler
        chain — the streamed sampler splits from the same key), population
        vectors, round index, accountant position, and the round history.
        The fault stream needs no state of its own — its position is the
        round index (`fl.faults`). Written atomically via
        `train.checkpoint.save` (temp-then-rename), so a crash mid-save
        never destroys the previous durable state."""
        if self.engine is None:
            raise ValueError("save_run_state/restore_run_state are "
                             "engine-backend features; use backend='engine'")
        est = jax.device_get(self._estate)
        tree = {"estate": {"params": est.params,
                           "opt_state": tuple(est.opt_state),
                           "key": np.asarray(est.key),
                           "last_round": np.asarray(est.last_round),
                           "participation": np.asarray(est.participation),
                           "round_idx": np.asarray(est.round_idx)}}
        checkpoint.save(Path(path), tree, meta={
            "kind": "trainer-run-state", "version": "1",
            "round_idx": str(self.state.round_idx),
            "accountant_rounds": str(self.accountant.rounds),
            "history": json.dumps(self.state.history)})

    def restore_run_state(self, path) -> int:
        """Restore a `save_run_state` snapshot and return the round index to
        resume from. Continuing for the remaining rounds reproduces the
        uninterrupted trajectory bit-exactly (the PRNG key, population
        vectors and fault-stream position — the round index — are all part
        of the snapshot)."""
        if self.engine is None:
            raise ValueError("save_run_state/restore_run_state are "
                             "engine-backend features; use backend='engine'")
        tree, meta = checkpoint.load(Path(path))
        if meta.get("kind") != "trainer-run-state":
            raise checkpoint.CheckpointError(
                f"{path} is not a trainer run-state snapshot "
                f"(kind={meta.get('kind')!r})")
        est = tree["estate"]
        state = EngineState(
            params=est["params"],
            opt_state=ServerOptState(*est["opt_state"]),
            key=jnp.asarray(est["key"]),
            last_round=jnp.asarray(est["last_round"]),
            participation=jnp.asarray(est["participation"]),
            round_idx=jnp.asarray(est["round_idx"]))
        if getattr(self.engine, "mesh", None) is not None:
            state = self.engine.place_state(state)
        else:
            state = jax.device_put(state)
        self._estate = state
        self.state.params = state.params
        self.state.opt_state = state.opt_state
        self.state.round_idx = int(meta["round_idx"])
        self.state.history = json.loads(meta["history"])
        self.accountant.restore_rounds(int(meta["accountant_rounds"]))
        n = self.engine.n_users
        self.participation = np.asarray(est["participation"], np.int64)[:n]
        self.pop.absorb_last_round(np.asarray(est["last_round"])[:n])
        return self.state.round_idx

    # ---------------------------------------------------------------- public

    def run_round(self) -> Dict:
        if self.backend == "host":
            return self._run_round_host()
        return self._train_engine(1)[-1]

    def train(self, rounds: int, log_every: int = 0) -> List[Dict]:
        if self.backend != "host":
            self._train_engine(rounds, log_every)
            return self.state.history
        for r in range(rounds):
            rec = self._run_round_host()
            if log_every and (r + 1) % log_every == 0:
                self._log(rec)
        return self.state.history

    @staticmethod
    def _log(rec: Dict) -> None:
        print(f"round {rec['round']:4d}  loss {rec['loss']:.4f}  "
              f"clipped {rec['frac_clipped']:.2f}  "
              f"norm {rec['mean_update_norm']:.3f}")
