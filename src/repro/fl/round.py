"""Federated round orchestration: sample → local train → Algorithm 1 server.

This is the *simulation* driver (CPU-scale, real data movement); the
production-shape distributed round is `repro.launch.steps.fed_train_step`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ClientConfig, DPConfig
from repro.core import accountant as acct
from repro.core.dp_fedavg import finalize_round, server_step
from repro.core.server_optim import ServerOptState, init_state
from repro.data.federated import FederatedDataset
from repro.fl.client import make_round_fn
from repro.fl.population import PopulationSim
from repro.fl.sampling import sample_round
from repro.models.api import Model


@dataclass
class TrainerState:
    params: object
    opt_state: ServerOptState
    round_idx: int = 0
    history: List[Dict] = field(default_factory=list)


class FederatedTrainer:
    """End-to-end DP-FedAvg trainer over a simulated device population."""

    def __init__(self, model: Model, dataset: FederatedDataset,
                 dp: DPConfig, client: ClientConfig,
                 pop: Optional[PopulationSim] = None, seed: int = 0,
                 n_local_batches: int = 4):
        self.model = model
        self.dataset = dataset
        self.dp = dp
        self.client = client
        self.n_local_batches = n_local_batches
        synth = [u.user_id for u in dataset.users if u.is_synthetic]
        self.pop = pop or PopulationSim(len(dataset.users),
                                        synthetic_ids=synth, seed=seed)
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.PRNGKey(seed)
        self._round_fn = make_round_fn(model, client, dp)
        self.accountant = acct.MomentsAccountant(
            q=dp.clients_per_round / max(len(dataset.users), 1),
            noise_multiplier=dp.noise_multiplier, sampling="wor")
        params = model.init(jax.random.PRNGKey(seed + 1))
        self.state = TrainerState(params, init_state(params))
        self.participation = np.zeros(len(dataset.users), np.int64)

    def _stack_clients(self, ids: np.ndarray):
        tensors = [self.dataset.user_tensor(int(u), self.client.batch_size,
                                            self.n_local_batches, self.rng)
                   for u in ids]
        return {k: jnp.asarray(np.stack([t[k] for t in tensors]))
                for k in tensors[0]}

    def run_round(self) -> Dict:
        s = self.state
        ids = sample_round(self.pop, self.rng, s.round_idx,
                           self.dp.clients_per_round)
        self.participation[ids] += 1
        stacked = self._stack_clients(ids)
        total, mean_norm, frac_clipped, loss = self._round_fn(s.params, stacked)
        self.key, sub = jax.random.split(self.key)
        delta, stats = finalize_round(total, len(ids), sub, self.dp,
                                      stats=(mean_norm, frac_clipped))
        s.params, s.opt_state = server_step(s.params, s.opt_state, delta,
                                            self.dp)
        self.accountant.step()
        s.round_idx += 1
        rec = {"round": s.round_idx, "loss": float(loss),
               "mean_update_norm": float(mean_norm),
               "frac_clipped": float(frac_clipped),
               "n_clients": int(len(ids)),
               "noise_std": float(stats.noise_std)}
        s.history.append(rec)
        return rec

    def train(self, rounds: int, log_every: int = 0) -> List[Dict]:
        for r in range(rounds):
            rec = self.run_round()
            if log_every and (r + 1) % log_every == 0:
                print(f"round {rec['round']:4d}  loss {rec['loss']:.4f}  "
                      f"clipped {rec['frac_clipped']:.2f}  "
                      f"norm {rec['mean_update_norm']:.3f}")
        return self.state.history
