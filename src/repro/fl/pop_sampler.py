"""Mesh-sharded O(N) population sampler: block-local Gumbel top-k.

The engine's default (``sampler="global"``) cohort selection is a monolithic
O(N) program on one device: a full-population availability draw, the
Pace-Steering weight pass, and ``jax.random.choice(replace=False)`` — a
Gumbel perturbation followed by a *full argsort over N*. At N = 10⁶ that
argsort alone is ~95% of the sample phase. This module provides the
``sampler="sharded"`` selection primitives: the population axis is laid out
in canonical blocks (the `fl.reduction` association trick applied to users
instead of cohort slots), every per-user draw comes from a *block-keyed*
stream, and selection is an exact Gumbel **top-k** — O(N log cohort) work
that shards over the ``(pod, data)`` mesh with only O(cohort) candidates
crossing shard boundaries.

Parity contract (the per-block sampler PRNG layout)
---------------------------------------------------

The sharded sampler is a *different* sampler family than ``"global"`` (its
PRNG stream differs from ``jax.random.choice``'s), but within the family its
trajectories are bit-exact across every execution topology. Three rules make
that hold by construction, and they are load-bearing — treat them as a
frozen contract (tests/test_sampler_sharded.py):

* **block-keyed draws** — the padded population axis (``pop_pad(n_users)``
  rows) splits into :data:`~repro.fl.reduction.CANON_BLOCKS` equal
  contiguous blocks; block ``b``'s availability / Gumbel / Bernoulli
  uniforms are drawn from ``fold_in(key, b)``, **never** from a single
  population-shaped draw. A shard owns a contiguous group of whole blocks,
  so every topology generates identical per-user randomness.
* **total-order selection** — a candidate's rank is the lexicographic pair
  ``(-score, user_id)`` with the f32 score mapped to order-isomorphic int32
  bits (:func:`sortable_f32`). The K best under a total order are a
  *unique set in a unique order*, so flat top-k on one device and per-shard
  top-k merged through :func:`merge_topk` agree bitwise — an identity, not
  an approximation (the global lex top-K is contained in the union of
  per-shard lex top-k's). Per-shard ties rely on ``jax.lax.top_k``
  returning equal values lowest-index-first; the adversarial-tie property
  test pins that platform behavior. The per-shard top-k itself runs
  through :func:`blocked_topk` — a chunk-max-pruned evaluation that is
  bit-identical to ``lax.top_k`` (same values, same stable ties) but
  skips XLA's whole-shard sort, which would otherwise dominate the
  sample phase at fleet N.
* **index-order Poisson packing** — a Poisson round's buffer holds the
  first ``buffer`` selected users in global index order; per-shard packing
  + :func:`merge_poisson`'s sort reproduces exactly that set (within a
  shard, local index order *is* global index order).

Population-vector updates (``last_round`` / ``participation``) are
O(cohort) masked scatters against the shard's local rows — nothing O(N)
ever crosses the mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.fl.reduction import canon_pad, n_canon_blocks

__all__ = ["INT32_MIN", "block_gumbels", "block_uniforms", "blocked_topk",
           "gather_shards", "merge_poisson", "merge_topk", "pack_selected",
           "pop_pad", "n_pop_blocks", "scatter_max", "scatter_add",
           "shard_rank", "sortable_f32"]

# Reserved sentinel sort key for padded (beyond-n_users) rows: strictly below
# every real score's key (even -inf maps above it), so padding can never be
# selected while cohort <= n_users.
INT32_MIN = jnp.int32(-(2 ** 31))


def pop_pad(n_users: int, num_shards: int = 1, num_pods: int = 1) -> int:
    """Padded population-axis length: smallest multiple of the canonical
    population block count ≥ ``n_users``. Identical for every topology whose
    ``num_pods · num_shards`` divides `reduction.CANON_BLOCKS` — the same
    rule (and the same reason) as the cohort buffer's `reduction.canon_pad`:
    a topology-independent block grid is what makes the block-keyed draws
    land on the same users everywhere."""
    return canon_pad(n_users, num_shards, num_pods)


def n_pop_blocks(num_shards: int = 1, num_pods: int = 1) -> int:
    """Population block count — the cohort reduction's
    `reduction.n_canon_blocks` rule applied to the user axis."""
    return n_canon_blocks(num_shards, num_pods)


def shard_rank(axes, num_shards: int):
    """Pod-major linear shard rank inside a ``shard_map`` body — matches the
    pod-major cohort layout, so shard ``r`` owns population rows
    ``[r·n_loc, (r+1)·n_loc)``."""
    if len(axes) == 1:
        return jax.lax.axis_index(axes[0])
    return (jax.lax.axis_index(axes[0]) * num_shards
            + jax.lax.axis_index(axes[1]))


def block_uniforms(key, block_ids, blk: int):
    """(n_blocks_local, blk) uniforms, block ``b`` drawn from
    ``fold_in(key, b)`` — the topology-independent per-user stream."""
    return jax.vmap(
        lambda b: jax.random.uniform(jax.random.fold_in(key, b), (blk,))
    )(block_ids)


def block_gumbels(key, block_ids, blk: int):
    """(n_blocks_local, blk) standard Gumbel draws, block-keyed like
    :func:`block_uniforms`."""
    return jax.vmap(
        lambda b: jax.random.gumbel(jax.random.fold_in(key, b), (blk,))
    )(block_ids)


def sortable_f32(x):
    """Map f32 → int32 preserving order: ``a < b  ⟺  s(a) < s(b)`` (signed
    int compare), for every finite value and ±inf. Sign-magnitude float bits
    become two's-complement by flipping negative values' magnitude bits
    (``~u``) and re-centering (``^ INT32_MIN``); non-negative floats are
    already correctly ordered as int32. NaN maps above +inf (scores are
    log-weight + Gumbel — finite by construction)."""
    u = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    return jnp.where(u < 0, jnp.bitwise_xor(~u, INT32_MIN), u)


def gather_shards(x, axes):
    """all_gather a per-shard candidate array into the replicated pod-major
    concatenation: (k, ...) local → (S·k, ...), shard ``r``'s slice at
    ``[r·k, (r+1)·k)``. Carries raw candidates only — no arithmetic — so
    every shard merges the identical list."""
    g = jax.lax.all_gather(x, axes[-1])
    if len(axes) == 2:
        g = jax.lax.all_gather(g, axes[0])
    return g.reshape((-1,) + x.shape[1:])


def blocked_topk(skey, k: int, chunk: int = 256):
    """Exact drop-in for ``jax.lax.top_k(skey, k)`` (bit-identical values
    *and* stable lowest-index tie-break) that prunes with contiguous chunk
    maxima first — XLA's CPU top-k over a whole shard is ~95% of the sample
    phase at fleet N, while this is one O(n) max-reduce, a top-k over
    ``n/chunk`` chunk maxima, and a lex sort of ``k·chunk`` candidates.

    Exactness: rank chunks by ``(max, chunk_index)`` (``lax.top_k``'s own
    stable order) and keep the best ``k``. Chunks are *contiguous* in index,
    so if element ``x``'s chunk is not kept, each of the ``k`` kept chunks
    holds a maximum that either strictly beats ``x`` or ties it with a
    strictly smaller index (the kept chunk's index — hence all its indices —
    is smaller than ``x``'s) — ``k`` elements ranked above ``x``, so ``x``
    is not in the stable top-k. Tail padding uses :data:`INT32_MIN` at
    indices ≥ n, which loses every tie to real rows by the index order."""
    n = skey.shape[0]
    if n < chunk * k:          # pruning can't win (or c < k): direct top-k
        return jax.lax.top_k(skey, k)
    c = -(-n // chunk)
    if c * chunk != n:
        skey = jnp.concatenate(
            [skey, jnp.full((c * chunk - n,), INT32_MIN, skey.dtype)])
    tiles = skey.reshape(c, chunk)
    _, cidx = jax.lax.top_k(jnp.max(tiles, axis=1), k)
    cand = tiles[cidx].reshape(-1)
    lidx = (cidx[:, None] * chunk
            + jnp.arange(chunk)).reshape(-1).astype(jnp.int32)
    sneg, sidx = jax.lax.sort((~cand, lidx), num_keys=2)
    return ~sneg[:k], sidx[:k]


def merge_topk(vals, gids, k: int):
    """Canonical merge of per-shard top-k candidates: ascending lex sort on
    ``(~vals, gids)`` — i.e. score descending, user id ascending on ties (a
    total order, so any merge bracketing yields this same result) — and the
    first ``k`` user ids are the global lex top-K. ``vals`` are
    :func:`sortable_f32` keys; ``~`` is the overflow-free order reversal."""
    _, ids = jax.lax.sort((~vals, gids), num_keys=2)
    return ids[:k]


def pack_selected(sel, buffer: int, offset):
    """Per-shard Poisson packing: the first ``buffer`` selected *local* rows
    in index order as global user ids, vacant candidate slots filled with
    the int32 max sentinel (sorts after every real id in
    :func:`merge_poisson`). Returns ``(gids (buffer,), count ())``."""
    n_loc = sel.shape[0]
    lidx = jnp.nonzero(sel, size=buffer, fill_value=n_loc)[0]
    gids = jnp.where(lidx < n_loc, lidx + offset, jnp.iinfo(jnp.int32).max
                     ).astype(jnp.int32)
    return gids, jnp.minimum(jnp.sum(sel), buffer)


def merge_poisson(gids_all, counts_all, buffer: int):
    """Merge per-shard Poisson candidate lists into the exact global
    packing: ascending sort puts real ids in global index order (sentinels
    last), and the first ``buffer`` are precisely the globally-first
    ``buffer`` selected users — each belongs to its shard's first
    ``buffer``, so per-shard truncation never drops one. Returns
    ``(ids (buffer,), slot_mask (buffer,))`` with vacant slots id 0, like
    `engine.poisson_select`."""
    merged = jnp.sort(gids_all)[:buffer]
    n_took = jnp.minimum(jnp.sum(counts_all), buffer)
    slot_mask = jnp.arange(buffer) < n_took
    return jnp.where(slot_mask, merged, 0), slot_mask


def scatter_max(vec, ids, mask, value, offset):
    """O(cohort) masked scatter-max of ``value`` into the shard's local rows
    (``vec`` (n_loc,)): out-of-shard or masked slots contribute the int32
    minimum — a no-op under max. Duplicate padded ids are safe (max folds
    them)."""
    n_loc = vec.shape[0]
    lid = ids - offset
    ok = mask & (lid >= 0) & (lid < n_loc)
    return vec.at[jnp.clip(lid, 0, n_loc - 1)].max(
        jnp.where(ok, value, INT32_MIN))


def scatter_add(vec, ids, mask, offset):
    """O(cohort) masked scatter-add of 1 into the shard's local rows:
    out-of-shard or masked slots add exactly 0."""
    n_loc = vec.shape[0]
    lid = ids - offset
    ok = mask & (lid >= 0) & (lid < n_loc)
    return vec.at[jnp.clip(lid, 0, n_loc - 1)].add(ok.astype(vec.dtype))
