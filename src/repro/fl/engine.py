"""Compiled multi-round DP-FedAvg simulation engine.

The host-loop trainer (`repro.fl.round.FederatedTrainer`, backend="host")
re-stacks client tensors with numpy and re-enters jit every round; at
thousands of simulated rounds (secret-sharer sweeps, Table 5/6/7/8
ablations) that host round-trip dominates wall clock. This engine keeps the
*entire* simulation on device and runs K federated rounds per jit call with
a single ``lax.scan``:

* **population** — per-round availability draws + Pace Steering weights
  computed on device from a ``last_round`` vector (the weight function is a
  hook, see :func:`pace_steering_weights`);
* **sampling** — fixed-size weighted sampling without replacement via
  ``jax.random.choice`` (Gumbel top-k under the hood, matching numpy's
  successive-draw semantics; zero-weight devices are never selected while
  ≥ cohort positive-weight devices exist);
* **data** — gather-based client batching from the padded device-resident
  corpus tensor built by ``FederatedDataset.to_device_arrays()``; no host
  data movement after engine construction;
* **round** — the clip → sum → noise → server-optimizer (Nesterov) step of
  Algorithm 1 fused into the scan body (`repro.fl.client.round_compute` +
  `repro.core.dp_fedavg.finalize_round`), with state buffers donated across
  calls;
* **eval hooks** — a user-supplied ``eval_fn(params, round_idx) -> pytree``
  evaluated *inside* the scan body every ``eval_every`` rounds (a masked
  ``lax.cond`` skips the computation on the other rounds), with stacked
  per-round outputs returned in the history next to the training metrics.
  This is what makes memorization-vs-round curves (in-scan canary
  log-perplexity, paper Fig. style) practical at thousands of rounds;
* **Poisson rounds** — ``sampling="poisson"`` draws each available device
  i.i.d. Bernoulli(q = qN/N) per round [MRTZ17]. Rounds are variable-size
  but shapes stay static: the first ``poisson_buffer`` selected devices fill
  a fixed-shape cohort buffer and a 0/1 slot mask is folded into the
  weighted sum (`round_compute(mask=...)`); Δ̄ and σ keep the DPConfig
  calibration z·S/(qN) against the *expected* round size.

`run` (compiled scan) and `run_python` (per-round jit, Python loop) execute
the *same* traced round body from the same PRNG stream, so they sample
identical cohorts and are numerically interchangeable — `tests/test_engine.py`
asserts trajectory parity and zero-noise bit-exactness.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ClientConfig, DPConfig
from repro.core.dp_fedavg import finalize_round, server_step
from repro.core.server_optim import ServerOptState, init_state
from repro.data.tokenizer import PAD
from repro.fl.client import round_compute
from repro.models.api import Model


class EngineState(NamedTuple):
    """Device-resident simulation state threaded through the round scan."""

    params: Any
    opt_state: ServerOptState
    key: jax.Array            # PRNG stream (split once per round)
    last_round: jax.Array     # (N,) int32 — last participation, Pace Steering
    participation: jax.Array  # (N,) int32 — per-device participation counts
    round_idx: jax.Array      # () int32


def pace_steering_weights(last_round, synthetic, round_idx,
                          cooldown: int, penalty: float):
    """Default weight hook — mirrors `PopulationSim.selection_weights`:
    devices that participated within ``cooldown`` rounds are deprioritized to
    ``penalty``; secret-sharer synthetic devices are exempt (paper §V-A)."""
    cooling = (round_idx - last_round) < cooldown
    cooling &= ~synthetic
    return jnp.where(cooling, penalty, 1.0)


# Stand-in weight for unavailable devices: log(1e-30) ≈ -69 is far below any
# Gumbel perturbation of a real weight, so they are never chosen while ≥
# cohort available devices exist — but rounds stay fixed-size (and p stays
# finite) when an availability draw comes up short.
_UNAVAILABLE_W = 1e-30


def sample_cohort(key, weights, available, cohort: int):
    """Fixed-size weighted sampling without replacement on device.

    Rounds are fixed-size by construction (Algorithm 1): if a round's
    check-in draw leaves fewer than ``cohort`` devices, the remainder is
    topped up from un-checked-in devices rather than shrinking the round
    (the host loop does the opposite — see ``SimEngine`` for the warning
    when a configuration makes that regime likely)."""
    w = jnp.where(available, weights, _UNAVAILABLE_W).astype(jnp.float32)
    p = w / jnp.sum(w)
    return jax.random.choice(key, w.shape[0], (cohort,), replace=False, p=p)


def poisson_select(key, q: float, available, buffer: int):
    """Per-device Bernoulli(q) round composition [MRTZ17] with static shapes.

    Draws ``sel[i] ~ Bernoulli(q)`` for every *available* device, then packs
    the first ``buffer`` selected device ids (index order — a Poisson round
    is an unordered set) into a fixed-shape cohort buffer. Returns
    ``(ids (buffer,), slot_mask (buffer,) bool, took (N,) bool)`` where
    ``took`` marks exactly the devices occupying a buffer slot. Overflow
    beyond ``buffer`` is truncated; size the buffer ≥ qN + 4·√(qN) so that
    tail is negligible (`SimEngine` warns otherwise).
    """
    sel = (jax.random.uniform(key, available.shape) < q) & available
    took = sel & (jnp.cumsum(sel) <= buffer)
    ids = jnp.nonzero(took, size=buffer, fill_value=0)[0]
    slot_mask = jnp.arange(buffer) < jnp.sum(took)
    return ids, slot_mask, took


def gather_client_batches(examples, counts, ids, key,
                          n_batches: int, batch_size: int):
    """Build the (C, n_batches, B, S) client batch stack by pure gathers from
    the padded corpus tensor — the device-side analogue of
    ``FederatedDataset.user_tensor`` (uniform-per-example via per-user
    ``counts`` bounds; draws with replacement)."""
    C = ids.shape[0]
    need = n_batches * batch_size
    idx = jax.random.randint(key, (C, need), 0, counts[ids][:, None])
    emax = examples.shape[1]
    flat = examples.reshape((-1, examples.shape[-1]))
    rows = flat[ids[:, None] * emax + idx]              # (C, need, S+1)
    rows = rows.reshape(C, n_batches, batch_size, -1)
    batch = {"tokens": rows[..., :-1], "labels": rows[..., 1:]}
    batch["mask"] = (batch["labels"] != PAD).astype(jnp.float32)
    return batch


class SimEngine:
    """K-rounds-per-jit DP-FedAvg simulator over a device-resident population.

    ``data`` is the dict from ``FederatedDataset.to_device_arrays()``. The
    availability / Pace-Steering parameters mirror ``PopulationSim``; pass
    ``weight_fn(last_round, synthetic, round_idx) -> (N,) weights`` to
    replace the Pace-Steering prior (e.g. for sampling-skew ablations).

    ``sampling`` defaults to ``dp.sampling``: ``"fixed"`` rounds of exactly
    qN devices (Algorithm 1), or ``"poisson"`` variable-size rounds (each
    available device i.i.d. Bernoulli(qN/N); Pace-Steering weights don't
    apply — inclusion probability is uniform, matching the host
    ``sample_round(scheme="poisson")`` reference).

    ``eval_fn(params, round_idx) -> pytree`` runs inside the scan on the
    *post-update* params after rounds ``eval_every, 2·eval_every, …``; other
    rounds carry zeros (see history keys ``eval`` / ``eval_mask``).
    """

    def __init__(self, model: Model, data: Dict[str, np.ndarray],
                 dp: DPConfig, client: ClientConfig, *,
                 n_local_batches: int = 4, availability: float = 0.1,
                 pace_cooldown: int = 50, pace_penalty: float = 0.01,
                 rounds_per_call: int = 8,
                 weight_fn: Optional[Callable] = None,
                 sampling: Optional[str] = None,
                 poisson_buffer: Optional[int] = None,
                 eval_fn: Optional[Callable] = None, eval_every: int = 1):
        self.model = model
        self.dp = dp
        self.client = client
        self.n_local_batches = n_local_batches
        self.availability = availability
        self.rounds_per_call = max(int(rounds_per_call), 1)
        self.sampling = sampling or getattr(dp, "sampling", "fixed")
        if self.sampling not in ("fixed", "poisson"):
            raise ValueError(f"sampling must be 'fixed' or 'poisson', "
                             f"got {self.sampling!r}")
        self.eval_fn = eval_fn
        self.eval_every = max(int(eval_every), 1)
        self.examples = jnp.asarray(data["examples"])
        self.counts = jnp.asarray(data["counts"])
        self.synthetic = jnp.asarray(data["synthetic"])
        self.n_users = int(self.examples.shape[0])
        self.cohort = min(dp.clients_per_round, self.n_users)
        self.q = self.cohort / self.n_users
        if self.sampling == "poisson":
            buf = poisson_buffer or int(np.ceil(
                self.cohort + 4.0 * np.sqrt(self.cohort) + 4))
            self.buffer = min(self.n_users, buf)
            if self.buffer < self.cohort + 2 * np.sqrt(self.cohort) \
                    and self.buffer < self.n_users:
                import warnings
                warnings.warn(
                    f"SimEngine: poisson_buffer={self.buffer} is within 2σ "
                    f"of the expected round size qN={self.cohort}; rounds "
                    "will regularly be truncated (the clipped sum silently "
                    "drops the overflow). Raise poisson_buffer.",
                    stacklevel=2)
        else:
            self.buffer = self.cohort
        n_synth = int(np.asarray(data["synthetic"]).sum())
        expected_avail = availability * (self.n_users - n_synth) + n_synth
        if self.sampling == "fixed" and expected_avail < self.cohort:
            import warnings
            warnings.warn(
                f"SimEngine: expected check-ins ({expected_avail:.0f} = "
                f"{availability}·{self.n_users - n_synth} real + {n_synth} "
                f"synthetic) < cohort ({self.cohort}); fixed-size rounds "
                "will regularly be topped up from un-checked-in devices and "
                "σ = zS/qN assumes the full cohort. Raise availability / "
                "population or lower clients_per_round.", stacklevel=2)
        if self.sampling == "poisson" \
                and self.q * expected_avail < 0.9 * self.cohort:
            import warnings
            warnings.warn(
                f"SimEngine: Poisson rounds select Bernoulli(q={self.q:.3g})"
                f" among *available* devices — expected realized round size "
                f"({self.q * expected_avail:.0f}) is well below qN "
                f"({self.cohort}) while σ = zS/qN assumes qN. Per-round SNR "
                "will be worse than the DPConfig calibration implies; raise "
                "availability (MRTZ17 assumes the whole population is "
                "available) or lower clients_per_round.", stacklevel=2)
        self.weight_fn = weight_fn or (
            lambda last, synth, r: pace_steering_weights(
                last, synth, r, pace_cooldown, pace_penalty))
        self._compiled: Dict[int, Callable] = {}
        # reference path keeps its inputs alive (no donation) so tests can
        # replay the same initial state through both entry points
        self._one_round = jax.jit(self._round_body)

    # ------------------------------------------------------------------ state

    def init_state(self, params, seed: int = 0,
                   opt_state: Optional[ServerOptState] = None) -> EngineState:
        return EngineState(
            params=params,
            opt_state=opt_state if opt_state is not None else init_state(params),
            key=jax.random.PRNGKey(seed),
            last_round=jnp.full((self.n_users,), -(10 ** 9), jnp.int32),
            participation=jnp.zeros((self.n_users,), jnp.int32),
            round_idx=jnp.zeros((), jnp.int32))

    # ------------------------------------------------------------- round body

    def _round_body(self, state: EngineState, _=None
                    ) -> Tuple[EngineState, Dict[str, jax.Array]]:
        key, k_avail, k_sample, k_idx, k_noise = jax.random.split(state.key, 5)
        avail = (jax.random.uniform(k_avail, (self.n_users,))
                 < self.availability) | self.synthetic
        if self.sampling == "poisson":
            ids, mask, took = poisson_select(k_sample, self.q, avail,
                                             self.buffer)
            last_round = jnp.where(took, state.round_idx, state.last_round)
            participation = state.participation + took.astype(jnp.int32)
            n_clients = jnp.sum(took).astype(jnp.int32)
        else:
            w = self.weight_fn(state.last_round, self.synthetic,
                               state.round_idx)
            ids = sample_cohort(k_sample, w, avail, self.cohort)
            mask = None
            last_round = state.last_round.at[ids].set(state.round_idx)
            participation = state.participation.at[ids].add(1)
            n_clients = jnp.asarray(self.cohort, jnp.int32)
        batches = gather_client_batches(self.examples, self.counts, ids,
                                        k_idx, self.n_local_batches,
                                        self.client.batch_size)
        total, mean_norm, frac_clipped, loss = round_compute(
            self.model, state.params, batches, self.client, self.dp,
            mask=mask)
        # Δ̄ and σ are calibrated against qN — the exact round size in fixed
        # mode, the *expected* one under Poisson sampling [MRTZ17].
        delta, stats = finalize_round(total, self.cohort, k_noise, self.dp,
                                      stats=(mean_norm, frac_clipped))
        params, opt_state = server_step(state.params, state.opt_state, delta,
                                        self.dp)
        new_state = EngineState(params, opt_state, key, last_round,
                                participation, state.round_idx + 1)
        rec = {"loss": loss, "mean_update_norm": mean_norm,
               "frac_clipped": frac_clipped, "noise_std": stats.noise_std,
               "n_clients": n_clients}
        if self.eval_fn is not None:
            do = ((state.round_idx + 1) % self.eval_every) == 0
            out_shapes = jax.eval_shape(self.eval_fn, params, state.round_idx)
            zeros = jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), out_shapes)
            rec["eval"] = jax.lax.cond(
                do, lambda p: self.eval_fn(p, state.round_idx),
                lambda p: zeros, params)
            rec["eval_mask"] = do
        return new_state, rec

    def _run_k(self, k: int) -> Callable:
        """jit of a k-round scan with state-buffer donation (params/opt/
        population vectors are updated in place across chunk calls)."""
        if k not in self._compiled:
            def run(state):
                return jax.lax.scan(self._round_body, state, None, length=k)
            self._compiled[k] = jax.jit(run, donate_argnums=0)
        return self._compiled[k]

    # ------------------------------------------------------------------ entry

    def run(self, state: EngineState, n_rounds: int
            ) -> Tuple[EngineState, Dict[str, np.ndarray]]:
        """Compiled path: scan ``rounds_per_call`` rounds per jit call.
        Returns (state, history pytree of arrays with a leading (n_rounds,)
        axis — scalars per round for the training metrics, the stacked
        ``eval_fn`` output pytree under ``"eval"`` when a hook is set)."""
        if n_rounds <= 0:
            return state, {}
        hists = []
        left = n_rounds
        while left > 0:
            k = min(self.rounds_per_call, left)
            state, h = self._run_k(k)(state)
            hists.append(jax.device_get(h))
            left -= k
        hist = jax.tree_util.tree_map(
            lambda *xs: np.concatenate(xs), *hists)
        return state, hist

    def run_python(self, state: EngineState, n_rounds: int
                   ) -> Tuple[EngineState, Dict[str, np.ndarray]]:
        """Reference path: the same round body, one jit entry per round.
        Consumes the identical PRNG stream as :meth:`run`, so cohorts,
        batches, and noise match round for round."""
        if n_rounds <= 0:
            return state, {}
        recs = []
        for _ in range(n_rounds):
            state, rec = self._one_round(state)
            recs.append(jax.device_get(rec))
        hist = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *recs)
        return state, hist
