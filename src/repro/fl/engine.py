"""Compiled multi-round DP-FedAvg simulation engine.

The host-loop trainer (`repro.fl.round.FederatedTrainer`, backend="host")
re-stacks client tensors with numpy and re-enters jit every round; at
thousands of simulated rounds (secret-sharer sweeps, Table 5/6/7/8
ablations) that host round-trip dominates wall clock. This engine keeps the
*entire* simulation on device and runs K federated rounds per jit call with
a single ``lax.scan``:

* **population** — per-round availability draws + Pace Steering weights
  computed on device from a ``last_round`` vector (the weight function is a
  hook, see :func:`pace_steering_weights`);
* **sampling** — fixed-size weighted sampling without replacement via
  ``jax.random.choice`` (Gumbel top-k under the hood, matching numpy's
  successive-draw semantics; zero-weight devices are never selected while
  ≥ cohort positive-weight devices exist);
* **data** — gather-based client batching from the padded device-resident
  corpus tensor built by ``FederatedDataset.to_device_arrays()``; no host
  data movement after engine construction;
* **round** — the clip → sum → noise → server-optimizer (Nesterov) step of
  Algorithm 1 fused into the scan body (`repro.fl.client.round_compute` +
  `repro.core.dp_fedavg.finalize_round`), with state buffers donated across
  calls.

`run` (compiled scan) and `run_python` (per-round jit, Python loop) execute
the *same* traced round body from the same PRNG stream, so they sample
identical cohorts and are numerically interchangeable — `tests/test_engine.py`
asserts trajectory parity and zero-noise bit-exactness.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ClientConfig, DPConfig
from repro.core.dp_fedavg import finalize_round, server_step
from repro.core.server_optim import ServerOptState, init_state
from repro.data.tokenizer import PAD
from repro.fl.client import round_compute
from repro.models.api import Model


class EngineState(NamedTuple):
    """Device-resident simulation state threaded through the round scan."""

    params: Any
    opt_state: ServerOptState
    key: jax.Array            # PRNG stream (split once per round)
    last_round: jax.Array     # (N,) int32 — last participation, Pace Steering
    participation: jax.Array  # (N,) int32 — per-device participation counts
    round_idx: jax.Array      # () int32


def pace_steering_weights(last_round, synthetic, round_idx,
                          cooldown: int, penalty: float):
    """Default weight hook — mirrors `PopulationSim.selection_weights`:
    devices that participated within ``cooldown`` rounds are deprioritized to
    ``penalty``; secret-sharer synthetic devices are exempt (paper §V-A)."""
    cooling = (round_idx - last_round) < cooldown
    cooling &= ~synthetic
    return jnp.where(cooling, penalty, 1.0)


# Stand-in weight for unavailable devices: log(1e-30) ≈ -69 is far below any
# Gumbel perturbation of a real weight, so they are never chosen while ≥
# cohort available devices exist — but rounds stay fixed-size (and p stays
# finite) when an availability draw comes up short.
_UNAVAILABLE_W = 1e-30


def sample_cohort(key, weights, available, cohort: int):
    """Fixed-size weighted sampling without replacement on device.

    Rounds are fixed-size by construction (Algorithm 1): if a round's
    check-in draw leaves fewer than ``cohort`` devices, the remainder is
    topped up from un-checked-in devices rather than shrinking the round
    (the host loop does the opposite — see ``SimEngine`` for the warning
    when a configuration makes that regime likely)."""
    w = jnp.where(available, weights, _UNAVAILABLE_W).astype(jnp.float32)
    p = w / jnp.sum(w)
    return jax.random.choice(key, w.shape[0], (cohort,), replace=False, p=p)


def gather_client_batches(examples, counts, ids, key,
                          n_batches: int, batch_size: int):
    """Build the (C, n_batches, B, S) client batch stack by pure gathers from
    the padded corpus tensor — the device-side analogue of
    ``FederatedDataset.user_tensor`` (uniform-per-example via per-user
    ``counts`` bounds; draws with replacement)."""
    C = ids.shape[0]
    need = n_batches * batch_size
    idx = jax.random.randint(key, (C, need), 0, counts[ids][:, None])
    emax = examples.shape[1]
    flat = examples.reshape((-1, examples.shape[-1]))
    rows = flat[ids[:, None] * emax + idx]              # (C, need, S+1)
    rows = rows.reshape(C, n_batches, batch_size, -1)
    batch = {"tokens": rows[..., :-1], "labels": rows[..., 1:]}
    batch["mask"] = (batch["labels"] != PAD).astype(jnp.float32)
    return batch


class SimEngine:
    """K-rounds-per-jit DP-FedAvg simulator over a device-resident population.

    ``data`` is the dict from ``FederatedDataset.to_device_arrays()``. The
    availability / Pace-Steering parameters mirror ``PopulationSim``; pass
    ``weight_fn(last_round, synthetic, round_idx) -> (N,) weights`` to
    replace the Pace-Steering prior (e.g. for sampling-skew ablations).
    """

    def __init__(self, model: Model, data: Dict[str, np.ndarray],
                 dp: DPConfig, client: ClientConfig, *,
                 n_local_batches: int = 4, availability: float = 0.1,
                 pace_cooldown: int = 50, pace_penalty: float = 0.01,
                 rounds_per_call: int = 8,
                 weight_fn: Optional[Callable] = None):
        self.model = model
        self.dp = dp
        self.client = client
        self.n_local_batches = n_local_batches
        self.availability = availability
        self.rounds_per_call = max(int(rounds_per_call), 1)
        self.examples = jnp.asarray(data["examples"])
        self.counts = jnp.asarray(data["counts"])
        self.synthetic = jnp.asarray(data["synthetic"])
        self.n_users = int(self.examples.shape[0])
        self.cohort = min(dp.clients_per_round, self.n_users)
        n_synth = int(np.asarray(data["synthetic"]).sum())
        expected_avail = availability * (self.n_users - n_synth) + n_synth
        if expected_avail < self.cohort:
            import warnings
            warnings.warn(
                f"SimEngine: expected check-ins ({expected_avail:.0f} = "
                f"{availability}·{self.n_users - n_synth} real + {n_synth} "
                f"synthetic) < cohort ({self.cohort}); fixed-size rounds "
                "will regularly be topped up from un-checked-in devices and "
                "σ = zS/qN assumes the full cohort. Raise availability / "
                "population or lower clients_per_round.", stacklevel=2)
        self.weight_fn = weight_fn or (
            lambda last, synth, r: pace_steering_weights(
                last, synth, r, pace_cooldown, pace_penalty))
        self._compiled: Dict[int, Callable] = {}
        # reference path keeps its inputs alive (no donation) so tests can
        # replay the same initial state through both entry points
        self._one_round = jax.jit(self._round_body)

    # ------------------------------------------------------------------ state

    def init_state(self, params, seed: int = 0,
                   opt_state: Optional[ServerOptState] = None) -> EngineState:
        return EngineState(
            params=params,
            opt_state=opt_state if opt_state is not None else init_state(params),
            key=jax.random.PRNGKey(seed),
            last_round=jnp.full((self.n_users,), -(10 ** 9), jnp.int32),
            participation=jnp.zeros((self.n_users,), jnp.int32),
            round_idx=jnp.zeros((), jnp.int32))

    # ------------------------------------------------------------- round body

    def _round_body(self, state: EngineState, _=None
                    ) -> Tuple[EngineState, Dict[str, jax.Array]]:
        key, k_avail, k_sample, k_idx, k_noise = jax.random.split(state.key, 5)
        avail = (jax.random.uniform(k_avail, (self.n_users,))
                 < self.availability) | self.synthetic
        w = self.weight_fn(state.last_round, self.synthetic, state.round_idx)
        ids = sample_cohort(k_sample, w, avail, self.cohort)
        batches = gather_client_batches(self.examples, self.counts, ids,
                                        k_idx, self.n_local_batches,
                                        self.client.batch_size)
        total, mean_norm, frac_clipped, loss = round_compute(
            self.model, state.params, batches, self.client, self.dp)
        delta, stats = finalize_round(total, self.cohort, k_noise, self.dp,
                                      stats=(mean_norm, frac_clipped))
        params, opt_state = server_step(state.params, state.opt_state, delta,
                                        self.dp)
        new_state = EngineState(
            params, opt_state, key,
            state.last_round.at[ids].set(state.round_idx),
            state.participation.at[ids].add(1),
            state.round_idx + 1)
        rec = {"loss": loss, "mean_update_norm": mean_norm,
               "frac_clipped": frac_clipped, "noise_std": stats.noise_std}
        return new_state, rec

    def _run_k(self, k: int) -> Callable:
        """jit of a k-round scan with state-buffer donation (params/opt/
        population vectors are updated in place across chunk calls)."""
        if k not in self._compiled:
            def run(state):
                return jax.lax.scan(self._round_body, state, None, length=k)
            self._compiled[k] = jax.jit(run, donate_argnums=0)
        return self._compiled[k]

    # ------------------------------------------------------------------ entry

    def run(self, state: EngineState, n_rounds: int
            ) -> Tuple[EngineState, Dict[str, np.ndarray]]:
        """Compiled path: scan ``rounds_per_call`` rounds per jit call.
        Returns (state, history dict of (n_rounds,) numpy arrays)."""
        if n_rounds <= 0:
            return state, {}
        hists = []
        left = n_rounds
        while left > 0:
            k = min(self.rounds_per_call, left)
            state, h = self._run_k(k)(state)
            hists.append(jax.device_get(h))
            left -= k
        hist = {k: np.concatenate([h[k] for h in hists]) for k in hists[0]}
        return state, hist

    def run_python(self, state: EngineState, n_rounds: int
                   ) -> Tuple[EngineState, Dict[str, np.ndarray]]:
        """Reference path: the same round body, one jit entry per round.
        Consumes the identical PRNG stream as :meth:`run`, so cohorts,
        batches, and noise match round for round."""
        if n_rounds <= 0:
            return state, {}
        recs = []
        for _ in range(n_rounds):
            state, rec = self._one_round(state)
            recs.append(jax.device_get(rec))
        hist = {k: np.asarray([r[k] for r in recs]) for k in recs[0]}
        return state, hist
