"""Compiled multi-round DP-FedAvg simulation engine (cohort-sharded).

The host-loop trainer (`repro.fl.round.FederatedTrainer`, backend="host")
re-stacks client tensors with numpy and re-enters jit every round; at
thousands of simulated rounds (secret-sharer sweeps, Table 5/6/7/8
ablations) that host round-trip dominates wall clock. This engine keeps the
*entire* simulation on device and runs K federated rounds per jit call with
a single ``lax.scan``:

* **population** — per-round availability draws + Pace Steering weights
  computed on device from a ``last_round`` vector (the weight function is a
  hook, see :func:`pace_steering_weights`);
* **sampling** — fixed-size weighted sampling without replacement via
  ``jax.random.choice`` (Gumbel top-k under the hood, matching numpy's
  successive-draw semantics; zero-weight devices are never selected while
  ≥ cohort positive-weight devices exist);
* **data** — gather-based client batching from the padded device-resident
  corpus tensor built by ``FederatedDataset.to_device_arrays()``; no host
  data movement after engine construction;
* **round** — the clip → sum → noise → server-optimizer (Nesterov) step of
  Algorithm 1 fused into the scan body (`repro.fl.client` +
  `repro.core.dp_fedavg.finalize_round`), with state buffers donated across
  calls. The clipped sum is accumulated **streamingly**: inside each
  canonical block a ``lax.scan`` over contiguous ``cohort_chunk``-client
  chunks runs gather → local SGD → fused Pallas clip→accumulate
  (`kernels.dp_clip`) and folds straight into the block's running partial,
  so peak update memory is O(cohort_chunk·|params|) — not the materializing
  O(cohort·|params|) stack — and fully-masked padding chunks skip their
  compute via a scalar ``lax.cond``. The per-slot fold is strictly
  sequential (`fl.reduction.slot_fold` association), making trajectories
  bit-identical across every ``cohort_chunk`` dividing the block size;
* **eval hooks** — a user-supplied ``eval_fn(params, round_idx) -> pytree``
  evaluated *inside* the scan body every ``eval_every`` rounds (a masked
  ``lax.cond`` skips the computation on the other rounds), with stacked
  per-round outputs returned in the history next to the training metrics;
* **Poisson rounds** — ``sampling="poisson"`` draws each available device
  i.i.d. Bernoulli(q = qN/N) per round [MRTZ17]. Rounds are variable-size
  but shapes stay static: the first ``poisson_buffer`` selected devices fill
  a fixed-shape cohort buffer and a 0/1 slot mask is folded into the
  clipped sum; Δ̄ and σ keep the DPConfig calibration z·S/(qN) against the
  *expected* round size.

Cohort sharding (``num_shards > 1`` / ``num_pods > 1``)
-------------------------------------------------------

The per-round cohort axis shards across a 1-D ``data`` mesh — or, with
``num_pods > 1``, the 2-D ``(pod, data)`` batch slice of the multi-pod
production mesh — with ``shard_map`` (`sharding.specs.sim_mesh_config` /
`launch.mesh.make_cohort_mesh`): client batching and the per-client clip
live per-shard, and a single collective reduction produces the global
clipped sum before the (replicated) noise/Nesterov server step. Cohort
sampling and the Poisson draw stay replicated — every shard sees the same
PRNG stream, so all shards agree on the cohort and noise is drawn once
(σ calibration is untouched by the topology). Params and the noise stream
are pod-replicated (hybrid-FSDP layout of `sharding.specs`): only the
round-sum block partials ever cross the inter-pod axis.

Because float addition is not associative, a naive per-shard partial sum +
``psum`` would make params drift with the shard count. Instead the engine
reduces through a **canonical block tree** (:func:`cohort_sum`): the padded
cohort buffer is split into :data:`CANON_BLOCKS` contiguous blocks whose
boundaries align with every supported shard boundary, each block is summed
locally, and the block partials are combined by a fixed pairwise tree. On
the 1-D mesh the shards ``all_gather`` the partials so the tree is
evaluated identically everywhere; on the 2-D mesh the gather runs in two
stages — each pod's contiguous block group is gathered over the intra-pod
``data`` axis and folded *pod-locally*, and only those pod partials cross
the expensive ``pod`` axis, where the same pairwise tree combines them
(`reduction.fold_pods`). Since :data:`CANON_BLOCKS` is a power of two the
two-level fold is a re-bracketing of the flat tree, so the result is
*bit-identical for every ``(num_pods, num_shards)`` whose product divides*
:data:`CANON_BLOCKS` — `tests/test_engine_sharded.py` and
`tests/test_engine_pods.py` assert zero-noise bit-exact trajectory parity
across shards {1, 2, 4, 8} and pods {1, 2, 4} — which is exactly the
property the DP analysis needs: the clipped-sum sensitivity bound S/(qN)
survives unchanged under any aggregation topology [MRTZ17].

Cohort / buffer sizes that don't divide the shard count are **padded**
(masked empty slots), never truncated — dropping devices would silently
shrink the round and break the σ = zS/(qN) calibration.

`run` (compiled scan) and `run_python` (per-round jit, Python loop) execute
the *same* traced round body from the same PRNG stream, so they sample
identical cohorts and are numerically interchangeable — `tests/test_engine.py`
asserts trajectory parity and zero-noise bit-exactness.

Streamed population backend (``population_backend="streamed"``)
---------------------------------------------------------------

The default (``"device"``) backend holds the whole padded corpus tensor on
device — O(N·E_max·seq_len) device memory, a hard wall at 10⁶–10⁷ users.
The streamed backend keeps the corpus host-resident behind a
`data.population_store.PopulationStore` (RAM, mmap shards on disk, or an
O(1) replicated view) and stages exactly one cohort per round:

* the K-round ``lax.scan`` becomes a **host-driven round loop** around two
  jitted bodies compiled once each — ``_sample_body`` (availability draw,
  Pace-Steering/Poisson cohort selection, population-vector updates; only
  O(N)-*vector* state ever touches the device) and ``_compute_body`` (the
  gather → local SGD → clip → noise → server step, donated params/opt);
* after round k's cohort ids come back from the sampler (a tiny transfer),
  the host gathers their rows from the store and ``jax.device_put``s them
  into one of **two ping-ponged (padded, E_max, seq_len+1) cohort
  buffers** while round k−1's chunked compute scan is still in flight —
  the ``cohort_chunk`` streaming boundaries (PR 4) are what the transfer
  overlaps. Per-round device corpus residency is O(2·cohort·E_max),
  independent of N;
* the sampler chain (PRNG key, ``last_round``, ``participation``) is
  independent of the params chain, which is what makes the one-round
  lookahead legal: round k+1's cohort is fully determined before round k's
  server step lands.

Bit-exactness: the sampler consumes the identical PRNG splits as the fused
device round body, and the compute body draws per-slot example indices from
the *same* per-slot keys against the same ``counts[u]`` bounds — gathering
``examples[u]`` from a staged host buffer instead of the device-resident
corpus tensor selects bit-identical token rows, so streamed trajectories
are **bit-exact against the device backend** across the whole
{pods} × {shards} × {chunk} parity grid (`tests/test_engine_streamed.py`).

Production fault model (``fault_config=FaultConfig(...)``)
----------------------------------------------------------

With a `fl.faults.FaultConfig` the engine runs the deployed round protocol
instead of the perfect-fleet simulation (paper §III; 1710.06963 §B):

* **over-selection** — each round samples ``ceil(target /
  expected_survival)`` clients (fixed mode; Poisson scales q the same way)
  so the expected survivor count is the full target cohort;
* **per-slot fates** — a seeded stream disjoint from the training PRNG
  chain (`fl.faults.fault_fates`) marks slots dropped / late / corrupt;
  dropped and late slots are masked out of the round sum (exact ±0, the
  Poisson-exclusion machinery), corrupt slots get non-finite values
  injected into their *update* and are rejected by the server-side guard
  (`fl.client.chunk_accumulate(guard_nonfinite=True)`) — again exact ±0;
* **report goal / abort** — the round *commits* only if accepted survivors
  reach ``report_goal``; otherwise the server step is skipped via
  ``lax.cond`` (params/opt state bit-unchanged — the noise draw still
  consumes its key so the PRNG stream is fate-independent) and the trainer
  records no accountant step for it. σ **and** the released mean are
  calibrated to ``report_goal``, never the realized count, preserving the
  sensitivity bound S/report_goal whatever the fleet does.

``fault_config=None`` (the default) traces literally the fault-free round
program — fault-off trajectories are bit-identical to the engine before the
fault model existed. Fault-on trajectories are deterministic in the fault
seed and bit-exact across the whole {pods} × {shards} × {chunk} ×
{device, streamed} grid, because fates are slot-level and replicated
(`tests/test_engine_faults.py`). Faults require the streaming accumulation
path (``cohort_chunk > 0``) — the guard lives in the per-slot fold.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ClientConfig, DPConfig, MeshConfig
from repro.core.clipping import CLIP_PATHS
from repro.core.dp_fedavg import finalize_round, server_step
from repro.core.server_optim import ServerOptState, init_state
from repro.data.population_store import PopulationStore, as_population_store
from repro.data.tokenizer import PAD
from repro.fl import pop_sampler
from repro.fl.client import (client_updates, local_deltas,
                             stream_block_sums)
from repro.fl.faults import FaultConfig, fault_fates
# The canonical-reduction primitives live in `repro.fl.reduction` (shared
# with the host round body); re-exported here for backwards compatibility.
from repro.fl.reduction import (CANON_BLOCKS, block_sums as _block_sums,
                                canon_pad, cohort_sum,
                                fold_blocks as _fold_blocks, n_canon_blocks,
                                resolve_chunk)
from repro.launch.mesh import make_cohort_mesh
from repro.models.api import Model
from repro.sharding.specs import (batch_axes, cohort_spec,
                                  sim_mesh_config)
from repro.utils.compat import shard_map

__all__ = ["CANON_BLOCKS", "EngineState", "FaultConfig",
           "POPULATION_BACKENDS", "SAMPLERS", "SimEngine", "canon_pad",
           "cohort_sum", "gather_client_batches", "gather_cohort_batches",
           "n_canon_blocks", "pace_steering_weights", "poisson_select",
           "sample_cohort"]

POPULATION_BACKENDS = ("device", "streamed")
SAMPLERS = ("global", "sharded")


class EngineState(NamedTuple):
    """Device-resident simulation state threaded through the round scan."""

    params: Any
    opt_state: ServerOptState
    key: jax.Array            # PRNG stream (split once per round)
    last_round: jax.Array     # (N,) int32 — last participation, Pace Steering
    participation: jax.Array  # (N,) int32 — per-device participation counts
    round_idx: jax.Array      # () int32


def pace_steering_weights(last_round, synthetic, round_idx,
                          cooldown: int, penalty: float):
    """Default weight hook — mirrors `PopulationSim.selection_weights`:
    devices that participated within ``cooldown`` rounds are deprioritized to
    ``penalty``; secret-sharer synthetic devices are exempt (paper §V-A)."""
    cooling = (round_idx - last_round) < cooldown
    cooling &= ~synthetic
    return jnp.where(cooling, penalty, 1.0)


# Stand-in weight for unavailable devices: log(1e-30) ≈ -69 is far below any
# Gumbel perturbation of a real weight, so they are never chosen while ≥
# cohort available devices exist — but rounds stay fixed-size (and p stays
# finite) when an availability draw comes up short.
_UNAVAILABLE_W = 1e-30


def sample_cohort(key, weights, available, cohort: int):
    """Fixed-size weighted sampling without replacement on device.

    Rounds are fixed-size by construction (Algorithm 1): if a round's
    check-in draw leaves fewer than ``cohort`` devices, the remainder is
    topped up from un-checked-in devices rather than shrinking the round
    (the host loop does the opposite — see ``SimEngine`` for the warning
    when a configuration makes that regime likely)."""
    w = jnp.where(available, weights, _UNAVAILABLE_W).astype(jnp.float32)
    p = w / jnp.sum(w)
    return jax.random.choice(key, w.shape[0], (cohort,), replace=False, p=p)


def poisson_select(key, q: float, available, buffer: int):
    """Per-device Bernoulli(q) round composition [MRTZ17] with static shapes.

    Draws ``sel[i] ~ Bernoulli(q)`` for every *available* device, then packs
    the first ``buffer`` selected device ids (index order — a Poisson round
    is an unordered set) into a fixed-shape cohort buffer. Returns
    ``(ids (buffer,), slot_mask (buffer,) bool, took (N,) bool)`` where
    ``took`` marks exactly the devices occupying a buffer slot. Overflow
    beyond ``buffer`` is truncated; size the buffer ≥ qN + 4·√(qN) so that
    tail is negligible (`SimEngine` warns otherwise).
    """
    sel = (jax.random.uniform(key, available.shape) < q) & available
    took = sel & (jnp.cumsum(sel) <= buffer)
    ids = jnp.nonzero(took, size=buffer, fill_value=0)[0]
    slot_mask = jnp.arange(buffer) < jnp.sum(took)
    return ids, slot_mask, took


def gather_client_batches(examples, counts, ids, keys,
                          n_batches: int, batch_size: int):
    """Build the (C, n_batches, B, S) client batch stack by pure gathers from
    the padded corpus tensor — the device-side analogue of
    ``FederatedDataset.user_tensor`` (uniform-per-example via per-user
    ``counts`` bounds; draws with replacement).

    ``keys`` is a (C,) stack of *per-slot* PRNG keys, split from the
    replicated round stream *before* the cohort axis is sharded — so a
    slot's example draw is independent of the shard count (bit-parity
    across shards), though it does depend on the slot position. Anything
    that re-packs or reorders buffer slots (e.g. per-shard compaction)
    would therefore change the draws; keep slot assignment replicated."""
    need = n_batches * batch_size

    def one(uid, key):
        idx = jax.random.randint(key, (need,), 0, counts[uid])
        return examples[uid][idx].reshape(n_batches, batch_size, -1)

    rows = jax.vmap(one)(ids, keys)                      # (C, nb, B, S+1)
    batch = {"tokens": rows[..., :-1], "labels": rows[..., 1:]}
    batch["mask"] = (batch["labels"] != PAD).astype(jnp.float32)
    return batch


def gather_cohort_batches(cohort_examples, cohort_counts, keys,
                          n_batches: int, batch_size: int):
    """Slot-aligned analogue of :func:`gather_client_batches` for the
    streamed population backend: the cohort's example rows arrive as a
    staged (C, E_max, seq_len+1) buffer (one row-block per *slot*, already
    host-gathered from the `PopulationStore`) instead of being gathered
    from the device-resident corpus tensor by user id.

    Bit-parity contract: ``cohort_examples[slot] == examples[ids[slot]]``
    and ``cohort_counts[slot] == counts[ids[slot]]`` by construction, and
    ``keys`` is the same per-slot key stack the device backend splits — so
    the uniform index draw and the selected token rows are bit-identical to
    the device backend's, whatever the population size behind the store."""
    need = n_batches * batch_size

    def one(ex_u, cnt, key):
        idx = jax.random.randint(key, (need,), 0, cnt)
        return ex_u[idx].reshape(n_batches, batch_size, -1)

    rows = jax.vmap(one)(cohort_examples, cohort_counts, keys)
    batch = {"tokens": rows[..., :-1], "labels": rows[..., 1:]}
    batch["mask"] = (batch["labels"] != PAD).astype(jnp.float32)
    return batch


class _SamplerState(NamedTuple):
    """Device-resident slice of :class:`EngineState` the streamed backend's
    sampler owns — deliberately disjoint from (params, opt_state), which is
    what makes the one-round cohort lookahead legal."""

    key: jax.Array
    last_round: jax.Array
    participation: jax.Array
    round_idx: jax.Array


class SimEngine:
    """K-rounds-per-jit DP-FedAvg simulator over a device-resident population.

    ``data`` is the dict from ``FederatedDataset.to_device_arrays()``. The
    availability / Pace-Steering parameters mirror ``PopulationSim``; pass
    ``weight_fn(last_round, synthetic, round_idx) -> (N,) weights`` to
    replace the Pace-Steering prior (e.g. for sampling-skew ablations).

    ``sampling`` defaults to ``dp.sampling``: ``"fixed"`` rounds of exactly
    qN devices (Algorithm 1), or ``"poisson"`` variable-size rounds (each
    available device i.i.d. Bernoulli(qN/N); Pace-Steering weights don't
    apply — inclusion probability is uniform, matching the host
    ``sample_round(scheme="poisson")`` reference).

    ``num_shards`` / ``num_pods`` (or an explicit cohort ``mesh_config``,
    see `sharding.specs.sim_mesh_config`) shard the cohort axis across
    ``num_pods × num_shards`` devices with ``shard_map`` — a 1-D ``data``
    mesh, or the 2-D ``(pod, data)`` batch slice of the production mesh
    when ``num_pods > 1``. Sampling, noise, and the server step stay
    replicated (params are pod-replicated; only round-sum block partials
    cross the inter-pod axis); only client batching + local training +
    clipping are per-shard, combined by the canonical reduction
    (:func:`cohort_sum` association — bit-identical for every topology
    whose ``num_pods · num_shards`` divides :data:`CANON_BLOCKS`). Needs
    ≥ ``num_pods × num_shards`` visible devices (on CPU force them with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).

    ``cohort_chunk`` streams the round: each canonical block's partial sum
    is accumulated ``cohort_chunk`` clients at a time (gather → local SGD →
    fused clip→accumulate per chunk), so peak update memory is
    O(cohort_chunk·|params|) instead of the materializing O(cohort·|params|)
    stack. The intra-block fold is strictly sequential per slot, so
    trajectories are **bit-identical across every chunk size dividing the
    block size** (padded cohort / :data:`CANON_BLOCKS`), composing with the
    cross-shard parity. ``None`` auto-selects (largest divisor ≤
    `reduction.DEFAULT_MAX_CHUNK`); ``0`` restores the materializing path
    (the validated reference / benchmark baseline — its XLA-reduction
    association is *not* bit-comparable to the streaming family).

    ``population_backend`` selects where the corpus lives: ``"device"``
    (default) keeps the whole padded tensor device-resident (``data`` is a
    ``to_device_arrays()`` dict or a `PopulationStore` to materialize);
    ``"streamed"`` keeps it host-resident behind a `PopulationStore`
    (``data`` may also be a dict — wrapped in-memory — or a store path) and
    stages one cohort per round through two ping-ponged device buffers with
    a one-round prefetch lookahead — O(2·cohort·E_max) device corpus
    residency independent of N, bit-exact against ``"device"`` (see the
    module docstring).

    ``sampler`` selects the cohort-selection implementation: ``"global"``
    (default) is the monolithic O(N)-on-one-device program — availability
    draw, Pace-Steering weights, ``jax.random.choice``'s Gumbel argsort —
    bit-identical to every pre-sampler-knob trajectory; ``"sharded"`` lays
    the population axis out in canonical blocks, draws per-block from
    fold-in-keyed streams, and selects by per-shard Gumbel **top-k** merged
    through a canonical lex sort (`fl.pop_sampler`) — an exact weighted
    sample that shards the O(N) state and work over the same mesh as the
    cohort, with only O(cohort) candidates crossing shards. The two are
    *different sampler families* (different PRNG layouts ⇒ different —
    equally valid — trajectories); within the sharded family trajectories
    are deterministic in the seed and bit-exact across {pods} × {shards} ×
    {chunk} × {device, streamed} × {fixed, poisson} × {faults on/off}.

    ``clip_path`` selects the per-client clip→accumulate implementation:
    ``"fused"`` (default) runs the flat-parameter Pallas ``dp_clip`` kernels
    (interpret mode on CPU, compiled on TPU); ``"tree"`` the pytree
    reference.

    ``eval_fn(params, round_idx) -> pytree`` runs inside the scan on the
    *post-update* params after rounds ``eval_every, 2·eval_every, …``; other
    rounds carry zeros (see history keys ``eval`` / ``eval_mask``).
    """

    def __init__(self, model: Model, data, dp: DPConfig,
                 client: ClientConfig, *,
                 n_local_batches: int = 4, availability: float = 0.1,
                 pace_cooldown: int = 50, pace_penalty: float = 0.01,
                 rounds_per_call: int = 8,
                 weight_fn: Optional[Callable] = None,
                 sampling: Optional[str] = None,
                 poisson_buffer: Optional[int] = None,
                 num_shards: int = 1, num_pods: int = 1,
                 mesh_config: Optional[MeshConfig] = None,
                 cohort_chunk: Optional[int] = None,
                 clip_path: str = "fused",
                 population_backend: str = "device",
                 sampler: str = "global",
                 fault_config: Optional[FaultConfig] = None,
                 eval_fn: Optional[Callable] = None, eval_every: int = 1):
        self.model = model
        self.dp = dp
        self.client = client
        self.n_local_batches = n_local_batches
        self.availability = availability
        self.rounds_per_call = max(int(rounds_per_call), 1)
        self.sampling = sampling or getattr(dp, "sampling", "fixed")
        if self.sampling not in ("fixed", "poisson"):
            raise ValueError(f"sampling must be 'fixed' or 'poisson', "
                             f"got {self.sampling!r}")
        if mesh_config is not None:
            axes = tuple(mesh_config.axes)
            if axes not in (("data",), ("pod", "data")):
                raise ValueError(
                    "SimEngine shards the cohort over its batch axes only "
                    f"— a ('data',) or ('pod', 'data') mesh; got "
                    f"{mesh_config}. Model-parallel axes are the launch "
                    "layer's job — pass sim_mesh_config(num_shards, "
                    "num_pods) or just num_shards/num_pods.")
            sizes = dict(zip(axes, mesh_config.shape))
            from_mesh = sizes["data"]
            from_mesh_pods = sizes.get("pod", 1)
            if num_shards not in (1, from_mesh):
                raise ValueError(
                    f"num_shards={num_shards} disagrees with mesh_config's "
                    f"data axis ({from_mesh} devices); pass one or the "
                    "other")
            if num_pods not in (1, from_mesh_pods):
                raise ValueError(
                    f"num_pods={num_pods} disagrees with mesh_config's pod "
                    f"axis ({from_mesh_pods} pods); pass one or the other")
            num_shards, num_pods = from_mesh, from_mesh_pods
        self.num_shards = int(num_shards)
        self.num_pods = int(num_pods)
        self._mesh_config = sim_mesh_config(self.num_shards, self.num_pods)
        # total devices the cohort axis shards over (pod-major layout)
        self.total_shards = self.num_pods * self.num_shards
        # the cohort axis shards over exactly the batch_axes of the mesh
        # config — same layout rule as the production client dimension
        self._cohort_pspec = cohort_spec(self._mesh_config)
        self.mesh = (make_cohort_mesh(self._mesh_config)
                     if self.total_shards > 1 else None)
        self.eval_fn = eval_fn
        self.eval_every = max(int(eval_every), 1)
        if population_backend not in POPULATION_BACKENDS:
            raise ValueError(f"population_backend must be one of "
                             f"{POPULATION_BACKENDS}, got "
                             f"{population_backend!r}")
        self.population_backend = population_backend
        if population_backend == "device":
            # whole-corpus device residency: the original O(N·E_max·seq_len)
            # layout (a PopulationStore materializes through device_arrays())
            if isinstance(data, PopulationStore):
                data = data.device_arrays()
            self.store = None
            self.examples = jnp.asarray(data["examples"])
            self.counts = jnp.asarray(data["counts"])
            synth_np = np.asarray(data["synthetic"], bool)
            self.emax = int(self.examples.shape[1])
            self.row_len = int(self.examples.shape[2])
        else:
            # host-resident corpus: only the per-user vectors + two staged
            # cohort buffers ever touch the device
            self.store = as_population_store(data)
            self.examples = self.counts = None
            synth_np = np.asarray(self.store.synthetic, bool)
            self.emax = self.store.emax
            self.row_len = self.store.row_len
        self.synthetic = jnp.asarray(synth_np)
        self.n_users = int(synth_np.shape[0])
        self.cohort = min(dp.clients_per_round, self.n_users)
        self.q = self.cohort / self.n_users
        if sampler not in SAMPLERS:
            raise ValueError(f"sampler must be one of {SAMPLERS}, "
                             f"got {sampler!r}")
        self.sampler = sampler
        if sampler == "sharded":
            # population axis laid out in canonical blocks (pop_sampler
            # parity contract): padded length + block grid are fixed across
            # every topology in the parity family, and the per-user vectors
            # (plus this synthetic mask) shard over the batch axes
            self.pop_blocks = pop_sampler.n_pop_blocks(self.num_shards,
                                                       self.num_pods)
            self.n_pad = pop_sampler.pop_pad(self.n_users, self.num_shards,
                                             self.num_pods)
            synth_pad = np.zeros(self.n_pad, bool)
            synth_pad[:self.n_users] = synth_np
            self._synth_pad = jnp.asarray(synth_pad)
            if self.mesh is not None:
                self._synth_pad = jax.device_put(
                    self._synth_pad,
                    NamedSharding(self.mesh, self._cohort_pspec))
        else:
            self.pop_blocks = None
            self.n_pad = self.n_users
            self._synth_pad = None
        # production fault model: over-select so the *expected* survivor
        # count is the target cohort, and calibrate σ (and the released
        # mean) to the report goal — never the realized survivor count.
        # With fault_config=None every derived quantity collapses to its
        # fault-free value, so the traced round program is unchanged.
        self.faults = fault_config
        if self.faults is not None:
            self.report_goal = self.faults.resolve_report_goal(self.cohort)
            self.sel_cohort = min(self.n_users,
                                  self.faults.over_selection(self.cohort))
            self.sel_q = min(1.0, self.q / self.faults.expected_survival
                             ) if self.faults.over_select else self.q
            self._fault_key = jax.random.PRNGKey(self.faults.seed)
            self._round_denom = self.report_goal
        else:
            self.report_goal = None
            self.sel_cohort = self.cohort
            self.sel_q = self.q
            self._fault_key = None
            self._round_denom = self.cohort
        if self.sampling == "poisson":
            exp_sel = (self.cohort if self.faults is None
                       else self.sel_q * self.n_users)
            buf = poisson_buffer or int(np.ceil(
                exp_sel + 4.0 * np.sqrt(exp_sel) + 4))
            # pad, never truncate: a buffer that doesn't divide the shard
            # count grows to the next canonical multiple (masked empty
            # slots) so no selected device is silently dropped
            self.buffer = canon_pad(min(self.n_users, buf), self.num_shards,
                                    self.num_pods)
            if self.buffer < self.cohort + 2 * np.sqrt(self.cohort) \
                    and self.buffer < self.n_users:
                import warnings
                warnings.warn(
                    f"SimEngine: poisson_buffer={self.buffer} is within 2σ "
                    f"of the expected round size qN={self.cohort}; rounds "
                    "will regularly be truncated (the clipped sum silently "
                    "drops the overflow). Raise poisson_buffer.",
                    stacklevel=2)
        else:
            self.buffer = self.sel_cohort
        # the physical per-round buffer: (over-)selected / poisson slots
        # padded to the canonical block grid (slot_mask zeroes the padding
        # exactly; sel_cohort == cohort whenever faults are off)
        self.padded = (self.buffer if self.sampling == "poisson"
                       else canon_pad(self.sel_cohort, self.num_shards,
                                      self.num_pods))
        self.n_blocks = n_canon_blocks(self.num_shards, self.num_pods)
        if self.padded % self.total_shards or self.padded % self.n_blocks:
            raise AssertionError(
                f"SimEngine internal error: padded cohort buffer "
                f"{self.padded} must be divisible by num_pods×num_shards="
                f"{self.total_shards} and n_blocks={self.n_blocks} — "
                "padding must never truncate devices (ragged cohorts pad "
                "up)")
        if clip_path not in CLIP_PATHS:
            raise ValueError(f"clip_path must be one of {CLIP_PATHS}, "
                             f"got {clip_path!r}")
        self.clip_path = clip_path
        # streaming accumulation: chunk size per canonical block (0 = the
        # legacy materializing path, kept for benchmarking/validation)
        self.cohort_chunk = resolve_chunk(cohort_chunk,
                                          self.padded // self.n_blocks)
        if self.faults is not None:
            if self.cohort_chunk == 0:
                raise ValueError(
                    "fault_config needs the streaming accumulation path "
                    "(cohort_chunk > 0): corrupt-report rejection lives in "
                    "the per-slot fold's guard_nonfinite — the materializing "
                    "cohort_chunk=0 path is the fault-free reference only")
            max_survivors = (self.sel_cohort if self.sampling == "fixed"
                             else self.padded)
            if self.report_goal > max_survivors:
                import warnings
                warnings.warn(
                    f"SimEngine: report_goal={self.report_goal} exceeds the "
                    f"per-round selection ({max_survivors} slots) — every "
                    "round will abort and the run can never make progress. "
                    "Lower report_goal or enable over_select.", stacklevel=2)
        n_synth = int(synth_np.sum())
        expected_avail = availability * (self.n_users - n_synth) + n_synth
        if self.sampling == "fixed" and expected_avail < self.sel_cohort:
            import warnings
            warnings.warn(
                f"SimEngine: expected check-ins ({expected_avail:.0f} = "
                f"{availability}·{self.n_users - n_synth} real + {n_synth} "
                f"synthetic) < cohort ({self.sel_cohort}); fixed-size rounds "
                "will regularly be topped up from un-checked-in devices and "
                "σ = zS/qN assumes the full cohort. Raise availability / "
                "population or lower clients_per_round.", stacklevel=2)
        if self.sampling == "poisson" \
                and self.q * expected_avail < 0.9 * self.cohort:
            import warnings
            warnings.warn(
                f"SimEngine: Poisson rounds select Bernoulli(q={self.q:.3g})"
                f" among *available* devices — expected realized round size "
                f"({self.q * expected_avail:.0f}) is well below qN "
                f"({self.cohort}) while σ = zS/qN assumes qN. Per-round SNR "
                "will be worse than the DPConfig calibration implies; raise "
                "availability (MRTZ17 assumes the whole population is "
                "available) or lower clients_per_round.", stacklevel=2)
        self.weight_fn = weight_fn or (
            lambda last, synth, r: pace_steering_weights(
                last, synth, r, pace_cooldown, pace_penalty))
        # batch-source dispatch: how a (cohort-sharded) tuple of per-slot
        # arrays becomes the (C, nb, B, S) client batch stack — by-user-id
        # gathers from the device corpus, or by-slot gathers from a staged
        # cohort buffer (see _batch_args for the matching tuple layout)
        if self.population_backend == "device":
            self._gather_batches = lambda a: gather_client_batches(
                self.examples, self.counts, a[0], a[1],
                self.n_local_batches, self.client.batch_size)
        else:
            self._gather_batches = lambda a: gather_cohort_batches(
                a[0], a[1], a[2], self.n_local_batches,
                self.client.batch_size)
        self._compiled: Dict[int, Callable] = {}
        # streamed backend: (sample_jit, compute_jit) per donation policy,
        # plus the two ping-ponged staged-cohort device buffer slots
        self._streamed_jits: Dict[bool, Tuple[Callable, Callable]] = {}
        self._cohort_sharding = (NamedSharding(self.mesh, self._cohort_pspec)
                                 if self.mesh is not None else None)
        self._inflight = [None, None]
        if self.population_backend == "device":
            # reference path keeps its inputs alive (no donation) so tests
            # can replay the same initial state through both entry points
            self._one_round = jax.jit(self._round_body)

    # ------------------------------------------------------------------ state

    def init_state(self, params, seed: int = 0,
                   opt_state: Optional[ServerOptState] = None) -> EngineState:
        # the sharded sampler owns (n_pad,) population vectors — padded to
        # the canonical population block grid and mesh-sharded; the global
        # sampler keeps the exact (n_users,) replicated layout
        state = EngineState(
            params=params,
            opt_state=opt_state if opt_state is not None else init_state(params),
            key=jax.random.PRNGKey(seed),
            last_round=jnp.full((self.n_pad,), -(10 ** 9), jnp.int32),
            participation=jnp.zeros((self.n_pad,), jnp.int32),
            round_idx=jnp.zeros((), jnp.int32))
        return self.place_state(state)

    def place_state(self, state: EngineState) -> EngineState:
        """Commit an :class:`EngineState` to the engine's device layout (the
        init / run-state-restore placement): everything replicated across
        the cohort mesh — except the population vectors under
        ``sampler="sharded"``, which shard over the batch axes so the
        donated round bodies keep one stable layout. No-op off-mesh."""
        if self.mesh is None:
            return state
        repl = NamedSharding(self.mesh, P())
        if self.sampler == "global":
            # commit replicated across the cohort mesh so the donated scan
            # carry keeps one stable layout (no resharding between chunks)
            return jax.device_put(state, NamedSharding(self.mesh, P()))
        pop = NamedSharding(self.mesh, self._cohort_pspec)
        return EngineState(
            params=jax.device_put(state.params, repl),
            opt_state=jax.device_put(state.opt_state, repl),
            key=jax.device_put(state.key, repl),
            last_round=jax.device_put(state.last_round, pop),
            participation=jax.device_put(state.participation, pop),
            round_idx=jax.device_put(state.round_idx, repl))

    # ------------------------------------------------------------- round body

    def _local_block_sums(self, params, batch_args, slot_mask,
                          n_blocks: int, corrupt=None):
        """Per-shard slice of the round: gather → local SGD → clip → masked
        canonical block partial sums. Returns (update-block pytree with a
        leading (n_blocks,) axis, (n_blocks, 4) stat blocks packing
        [Σ norms, Σ clipped-flags, Σ losses, Σ mask]). Streams
        ``cohort_chunk`` clients at a time unless ``cohort_chunk == 0``
        (the legacy materializing path).

        ``batch_args`` is the backend's per-slot batch-source tuple (every
        leaf carries a leading cohort-slot axis): ``(ids, keys)`` for the
        device-resident corpus, ``(cohort_examples, cohort_counts, keys)``
        for a staged cohort buffer — `_gather_batches` turns either into
        the (C, nb, B, S) client batch stack.

        ``corrupt`` (fault model only, (slots,) bool) marks slots whose
        report arrives as non-finite garbage — injected after local SGD,
        rejected by the fold's guard."""
        if self.cohort_chunk == 0:
            return self._materialized_block_sums(params, batch_args,
                                                 slot_mask, n_blocks)
        return self._streamed_block_sums(params, batch_args, slot_mask,
                                         n_blocks, corrupt)

    def _streamed_block_sums(self, params, batch_args, slot_mask,
                             n_blocks: int, corrupt=None):
        """Streaming accumulation: a scan over contiguous ``cohort_chunk``
        slices of each canonical block runs gather → local SGD per chunk and
        folds the chunk's clipped updates into the block's running partial
        (`fl.client.stream_block_sums`) — peak update memory is
        O(cohort_chunk·|params|), fully-masked padding chunks skip their
        compute, and the per-slot fold keeps the canonical intra-block
        association so every dividing chunk size is bit-identical.

        With ``corrupt`` the chunk compute poisons the marked slots' deltas
        and losses with NaN (multiplicative, so clean slots are bitwise
        untouched) and the fold runs with ``guard_nonfinite`` — the
        end-to-end corrupt-report injection + server-side rejection of the
        production fault model."""
        chunk = self.cohort_chunk
        cpb = slot_mask.shape[0] // (n_blocks * chunk)   # chunks per block
        shape3 = (n_blocks, cpb, chunk)
        args_r = jax.tree_util.tree_map(
            lambda l: l.reshape(shape3 + l.shape[1:]), batch_args)
        mask_r = slot_mask.astype(jnp.float32).reshape(shape3)

        if corrupt is None:
            def compute_chunk(inputs):
                batches = self._gather_batches(inputs)
                return local_deltas(self.model, params, batches, self.client)

            inputs, guard = args_r, False
        else:
            corrupt_r = corrupt.astype(jnp.float32).reshape(shape3)

            def compute_chunk(inputs):
                args, bad = inputs
                batches = self._gather_batches(args)
                deltas, losses = local_deltas(self.model, params, batches,
                                              self.client)
                # multiply by 1 (clean) or NaN (corrupt): x·1 is a bitwise
                # identity, x·NaN wrecks every element — the guard must
                # reject the whole report, not salvage parts of it
                poison = jnp.where(bad > 0, jnp.float32(jnp.nan),
                                   jnp.float32(1.0))
                deltas = jax.tree_util.tree_map(
                    lambda l: l * poison.reshape((-1,) + (1,) * (l.ndim - 1)),
                    deltas)
                return deltas, losses * poison

            inputs, guard = (args_r, corrupt_r), True

        return stream_block_sums(compute_chunk, inputs, mask_r,
                                 params, self.dp.clip_norm,
                                 clip_path=self.clip_path,
                                 guard_nonfinite=guard)

    def _materialized_block_sums(self, params, batch_args, slot_mask,
                                 n_blocks: int):
        """Legacy materializing path (``cohort_chunk=0``): vmap the whole
        padded slice, stack every clipped update, block-reduce once —
        O(cohort·|params|) peak memory, XLA-reduction association. Kept as
        the validated reference and the benchmark baseline."""
        batches = self._gather_batches(batch_args)
        clipped, norms, flags, losses = client_updates(
            self.model, params, batches, self.client, self.dp)
        m = slot_mask.astype(jnp.float32)
        tree = jax.tree_util.tree_map(
            lambda l: _block_sums(
                l.astype(jnp.float32) * m.reshape((-1,) + (1,) * (l.ndim - 1)),
                n_blocks),
            clipped)
        scal = _block_sums(jnp.stack([norms * m, flags * m, losses * m, m],
                                     axis=-1), n_blocks)
        return tree, scal

    def _cohort_sums(self, params, ids, keys, slot_mask, corrupt=None):
        """Device-backend entry: batch args are (ids, keys) gathers from the
        device-resident corpus tensor. See :meth:`_cohort_sums_from`."""
        return self._cohort_sums_from(params, (ids, keys), slot_mask,
                                      corrupt)

    def _cohort_sums_from(self, params, batch_args, slot_mask, corrupt=None):
        """Global masked clipped sum + stat sums over the padded cohort
        buffer — per-shard compute under ``shard_map``, combined by the
        canonical block tree so every (pod, shard) topology whose total
        divides the block count agrees bitwise. On the 2-D ``(pod, data)``
        mesh the reduction is hierarchical: each pod gathers and folds its
        own contiguous block group over the intra-pod ``data`` axis, and
        only those pod partials cross the inter-pod ``pod`` axis (where the
        same pairwise tree combines them — `reduction.fold_pods`
        association). ``batch_args`` leaves shard along their leading
        cohort-slot axis (same spec as ``slot_mask``); ``corrupt`` (fault
        model only) shards the same way — fates are slot-level, so the
        injection/rejection lands on the same slots whatever the
        topology."""
        if self.total_shards == 1:
            tree, scal = self._local_block_sums(params, batch_args,
                                                slot_mask, self.n_blocks,
                                                corrupt)
            return (jax.tree_util.tree_map(_fold_blocks, tree),
                    _fold_blocks(scal))

        cspec = self._cohort_pspec
        axes = batch_axes(self._mesh_config)  # ("data",) or ("pod", "data")
        data_axis = axes[-1]
        nblk_local = self.n_blocks // self.total_shards
        nblk_pod = self.n_blocks // self.num_pods

        def body(params, batch_args, slot_mask, corrupt=None):
            tree, scal = self._local_block_sums(params, batch_args,
                                                slot_mask, nblk_local,
                                                corrupt)
            # all_gather carries the raw block partials (no arithmetic), so
            # the pairwise tree below is evaluated identically — and with
            # the identical association — on every shard. The cohort layout
            # is pod-major, so gathering over the data axis yields this
            # pod's contiguous block group in canonical order.
            gather_d = lambda l: jax.lax.all_gather(l, data_axis).reshape(
                (nblk_pod,) + l.shape[1:])
            if self.num_pods == 1:
                tree = jax.tree_util.tree_map(gather_d, tree)
                return (jax.tree_util.tree_map(_fold_blocks, tree),
                        _fold_blocks(gather_d(scal)))
            # pod-local fold first: only the folded pod partials — one
            # |params|-sized value per pod, not per block — cross the
            # expensive inter-pod links
            pod_tree = jax.tree_util.tree_map(
                lambda l: _fold_blocks(gather_d(l)), tree)
            pod_scal = _fold_blocks(gather_d(scal))
            gather_p = lambda l: jax.lax.all_gather(l, "pod")
            tree = jax.tree_util.tree_map(
                lambda l: _fold_blocks(gather_p(l)), pod_tree)
            return tree, _fold_blocks(gather_p(pod_scal))

        # cspec is a pytree *prefix*: it shards every batch_args leaf along
        # its leading cohort-slot axis, whatever the backend's tuple layout.
        # The fault-free signature is kept verbatim so fault-off programs
        # trace exactly as before.
        if corrupt is None:
            sharded = shard_map(
                body, mesh=self.mesh,
                in_specs=(P(), cspec, cspec), out_specs=P())
            return sharded(params, batch_args, slot_mask)
        sharded = shard_map(
            body, mesh=self.mesh,
            in_specs=(P(), cspec, cspec, cspec), out_specs=P())
        return sharded(params, batch_args, slot_mask, corrupt)

    def _pop_shard_body(self, rank, k_avail, k_sample, round_idx,
                        last_round, participation, synthetic, axes=None):
        """One shard's slice of the sharded sampler round: block-keyed
        availability / score / Bernoulli draws over the shard's contiguous
        population rows, local candidate selection, the canonical
        (replicated) merge, fault fates, and the O(cohort) masked scatter
        updates of the local population-vector rows. Runs identically as
        the whole program when ``total_shards == 1`` (``rank=0``,
        ``axes=None`` skips the gathers) — the merge consumes the same
        candidate lists either way, which is the topology bit-exactness
        argument (see `fl.pop_sampler`)."""
        n_loc = last_round.shape[0]              # n_pad / total_shards
        nb_loc = self.pop_blocks // self.total_shards
        blk = n_loc // nb_loc
        offset = rank * n_loc
        block_ids = rank * nb_loc + jnp.arange(nb_loc)
        valid = (offset + jnp.arange(n_loc)) < self.n_users
        avail = ((pop_sampler.block_uniforms(k_avail, block_ids, blk)
                  .reshape(-1) < self.availability) | synthetic) & valid
        if self.sampling == "poisson":
            u = pop_sampler.block_uniforms(k_sample, block_ids, blk
                                           ).reshape(-1)
            sel = (u < self.sel_q) & avail
            gids, cnt = pop_sampler.pack_selected(sel, self.padded, offset)
            if axes is not None:
                gids = pop_sampler.gather_shards(gids, axes)
                cnt = pop_sampler.gather_shards(cnt[None], axes)
            ids, slot_mask = pop_sampler.merge_poisson(gids, cnt,
                                                       self.padded)
        else:
            w = self.weight_fn(last_round, synthetic, round_idx)
            g = pop_sampler.block_gumbels(k_sample, block_ids, blk
                                          ).reshape(-1)
            score = jnp.log(jnp.where(avail, w.astype(jnp.float32),
                                      _UNAVAILABLE_W)) + g
            skey = jnp.where(valid, pop_sampler.sortable_f32(score),
                             pop_sampler.INT32_MIN)
            k_loc = min(self.sel_cohort, n_loc)
            vals, lidx = pop_sampler.blocked_topk(skey, k_loc)
            gids = (offset + lidx).astype(jnp.int32)
            if axes is not None:
                vals = pop_sampler.gather_shards(vals, axes)
                gids = pop_sampler.gather_shards(gids, axes)
            cohort_ids = pop_sampler.merge_topk(vals, gids, self.sel_cohort)
            ids = jnp.pad(cohort_ids, (0, self.padded - self.sel_cohort))
            slot_mask = jnp.arange(self.padded) < self.sel_cohort
        if self.faults is None:
            report_mask, corrupt = slot_mask, None
        else:
            # replicated math from replicated inputs: every shard computes
            # the identical fates (the stream is slot-level, exactly as in
            # global mode)
            fates = fault_fates(self._fault_key, round_idx, self.padded,
                                self.faults)
            report_mask = slot_mask & fates.reported
            corrupt = report_mask & fates.corrupt
        # O(cohort) local scatters — same semantics as the global path:
        # last_round reacts to selection, participation to arrived reports
        last_round = pop_sampler.scatter_max(last_round, ids, slot_mask,
                                             round_idx, offset)
        part_mask = slot_mask if self.faults is None else report_mask
        participation = pop_sampler.scatter_add(participation, ids,
                                                part_mask, offset)
        out = (last_round, participation, ids, slot_mask, report_mask)
        return out if corrupt is None else out + (corrupt,)

    def _sharded_select(self, k_avail, k_sample, round_idx, last_round,
                        participation):
        """Dispatch :meth:`_pop_shard_body` — directly on one device, or
        under ``shard_map`` over the cohort mesh with the population
        vectors sharded along the batch axes and everything else
        replicated."""
        if self.total_shards == 1:
            out = self._pop_shard_body(0, k_avail, k_sample, round_idx,
                                       last_round, participation,
                                       self._synth_pad)
        else:
            axes = batch_axes(self._mesh_config)
            pspec = self._cohort_pspec

            def body(k_a, k_s, r, lr, part, synth):
                rank = pop_sampler.shard_rank(axes, self.num_shards)
                return self._pop_shard_body(rank, k_a, k_s, r, lr, part,
                                            synth, axes=axes)

            n_out = 5 if self.faults is None else 6
            out = shard_map(
                body, mesh=self.mesh,
                in_specs=(P(), P(), P(), pspec, pspec, pspec),
                out_specs=(pspec, pspec) + (P(),) * (n_out - 2))(
                    k_avail, k_sample, round_idx, last_round, participation,
                    self._synth_pad)
        if self.faults is None:
            return out + (None,)
        return out

    def _sample_phase(self, key, last_round, participation, round_idx):
        """The round's sampling prefix, shared verbatim by the device scan
        body (:meth:`_round_body`) and the streamed sampler body
        (:meth:`_sample_body`) — one definition is what guarantees both
        backends consume the identical PRNG stream. Draws availability,
        selects the (over-selected, with faults) cohort, resolves per-slot
        fault fates, and updates the population vectors.

        Returns ``(key', last_round', participation', (ids, slot_mask,
        report_mask, corrupt, keys, k_noise))``. With ``fault_config=None``
        the report mask *is* the slot mask and ``corrupt`` is None — the
        traced program is the pre-fault-model round prefix. Fault semantics:
        Pace Steering (``last_round``) reacts to *selection* — the server
        contacted the device whatever happened next — while
        ``participation`` counts only slots whose report actually arrived
        (dropped/late excluded; corrupt reports did arrive, so they
        count).

        ``sampler="sharded"`` swaps the monolithic selection (global
        availability draw + ``random.choice``'s argsort over N) for the
        block-local Gumbel top-k of `fl.pop_sampler` — a *different*
        sampler family (its PRNG layout is per-block), deterministic in the
        seed and bit-exact across topologies/backends/chunk sizes, sharing
        this same top-level key split so ``keys``/``k_noise`` (and hence
        the whole compute phase given a cohort) are family-independent."""
        key, k_avail, k_sample, k_idx, k_noise = jax.random.split(key, 5)
        if self.sampler == "sharded":
            last_round, participation, ids, slot_mask, report_mask, \
                corrupt = self._sharded_select(k_avail, k_sample, round_idx,
                                               last_round, participation)
            keys = jax.random.split(k_idx, self.padded)
            return (key, last_round, participation,
                    (ids, slot_mask, report_mask, corrupt, keys, k_noise))
        avail = (jax.random.uniform(k_avail, (self.n_users,))
                 < self.availability) | self.synthetic
        if self.sampling == "poisson":
            ids, slot_mask, took = poisson_select(k_sample, self.sel_q,
                                                  avail, self.padded)
        else:
            w = self.weight_fn(last_round, self.synthetic, round_idx)
            cohort_ids = sample_cohort(k_sample, w, avail, self.sel_cohort)
            ids = jnp.pad(cohort_ids, (0, self.padded - self.sel_cohort))
            slot_mask = jnp.arange(self.padded) < self.sel_cohort
        if self.faults is None:
            report_mask, corrupt = slot_mask, None
        else:
            # slot-level fates from the dedicated fault stream: replicated,
            # independent of the training chain, stateless in round_idx
            fates = fault_fates(self._fault_key, round_idx, self.padded,
                                self.faults)
            report_mask = slot_mask & fates.reported
            corrupt = report_mask & fates.corrupt
        if self.sampling == "poisson":
            last_round = jnp.where(took, round_idx, last_round)
            if self.faults is None:
                participation = participation + took.astype(jnp.int32)
            else:
                participation = participation.at[ids].add(
                    report_mask.astype(jnp.int32))
        else:
            # padded slots alias device 0 — scatter through the mask so they
            # never touch the population vectors
            last_round = last_round.at[ids].max(
                jnp.where(slot_mask, round_idx, jnp.int32(-(10 ** 9))))
            participation = participation.at[ids].add(
                (slot_mask if self.faults is None
                 else report_mask).astype(jnp.int32))
        keys = jax.random.split(k_idx, self.padded)
        return (key, last_round, participation,
                (ids, slot_mask, report_mask, corrupt, keys, k_noise))

    def _compute_phase(self, params, opt_state, round_idx, batch_args,
                       slot_mask, report_mask, corrupt, k_noise):
        """The round's compute suffix, shared by both backends: masked
        clipped sum over the reporting slots → finalize (noise) → server
        step — with the fault model, committed only if accepted survivors
        reach the report goal, otherwise aborted via ``lax.cond`` (params
        and opt state pass through bit-unchanged; the noise key was already
        consumed by the replicated draw, so the PRNG stream — and therefore
        every later round's sampling — is independent of the verdict)."""
        n_selected = jnp.sum(slot_mask).astype(jnp.int32)
        total, scal = self._cohort_sums_from(params, batch_args,
                                             report_mask, corrupt)
        denom = jnp.maximum(scal[3], 1.0)
        mean_norm, frac_clipped, loss = (scal[0] / denom, scal[1] / denom,
                                         scal[2] / denom)
        # Δ̄ and σ are calibrated against a *fixed* denominator — qN (the
        # exact fixed-mode round size / the expected Poisson one [MRTZ17]),
        # or the report goal under the fault model, never the realized
        # survivor count. The noise key is the replicated stream: one draw,
        # every shard agrees.
        delta, stats = finalize_round(total, self._round_denom, k_noise,
                                      self.dp,
                                      stats=(mean_norm, frac_clipped))
        if self.faults is None:
            params, opt_state = server_step(params, opt_state, delta,
                                            self.dp)
            rec = {"loss": loss, "mean_update_norm": mean_norm,
                   "frac_clipped": frac_clipped,
                   "noise_std": stats.noise_std, "n_clients": n_selected}
        else:
            # scal[3] = Σ report_mask minus guard-rejected slots: exactly
            # the usable reports the production server counts against the
            # report goal before deciding to commit
            n_accepted = scal[3].astype(jnp.int32)
            n_reported = jnp.sum(report_mask).astype(jnp.int32)
            committed = scal[3] >= jnp.float32(self.report_goal)
            params, opt_state = jax.lax.cond(
                committed,
                lambda po: server_step(po[0], po[1], delta, self.dp),
                lambda po: po,
                (params, opt_state))
            rec = {"loss": loss, "mean_update_norm": mean_norm,
                   "frac_clipped": frac_clipped,
                   "noise_std": stats.noise_std, "n_clients": n_accepted,
                   "n_selected": n_selected, "n_reported": n_reported,
                   "committed": committed}
        if self.eval_fn is not None:
            do = ((round_idx + 1) % self.eval_every) == 0
            out_shapes = jax.eval_shape(self.eval_fn, params, round_idx)
            zeros = jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), out_shapes)
            rec["eval"] = jax.lax.cond(
                do, lambda p: self.eval_fn(p, round_idx),
                lambda p: zeros, params)
            rec["eval_mask"] = do
        return params, opt_state, rec

    def _round_body(self, state: EngineState, _=None
                    ) -> Tuple[EngineState, Dict[str, jax.Array]]:
        key, last_round, participation, \
            (ids, slot_mask, report_mask, corrupt, keys, k_noise) = \
            self._sample_phase(state.key, state.last_round,
                               state.participation, state.round_idx)
        params, opt_state, rec = self._compute_phase(
            state.params, state.opt_state, state.round_idx, (ids, keys),
            slot_mask, report_mask, corrupt, k_noise)
        new_state = EngineState(params, opt_state, key, last_round,
                                participation, state.round_idx + 1)
        return new_state, rec

    def _run_k(self, k: int) -> Callable:
        """jit of a k-round scan with state-buffer donation (params/opt/
        population vectors are updated in place across chunk calls)."""
        if k not in self._compiled:
            def run(state):
                return jax.lax.scan(self._round_body, state, None, length=k)
            self._compiled[k] = jax.jit(run, donate_argnums=0)
        return self._compiled[k]

    # ------------------------------------------- streamed population backend

    def _sample_body(self, sstate: _SamplerState):
        """Round-k cohort selection + population-vector updates — delegating
        to the same :meth:`_sample_phase` the device scan body uses (so the
        streamed backend samples bit-identical cohorts and fault fates),
        owning only the O(N)-vector state. Returns the advanced sampler
        state plus everything the host needs to stage the cohort: ``(ids,
        slot/report/corrupt masks, per-slot keys, k_noise, this round's
        index)``."""
        key, last_round, participation, \
            (ids, slot_mask, report_mask, corrupt, keys, k_noise) = \
            self._sample_phase(sstate.key, sstate.last_round,
                               sstate.participation, sstate.round_idx)
        new = _SamplerState(key, last_round, participation,
                            sstate.round_idx + 1)
        return new, (ids, slot_mask, report_mask, corrupt, keys, k_noise,
                     sstate.round_idx)

    def _compute_body(self, params, opt_state, round_idx, cohort_examples,
                      cohort_counts, slot_mask, report_mask, corrupt, keys,
                      k_noise):
        """Round-k compute over a staged cohort buffer — the same
        :meth:`_compute_phase` suffix as the scan path, reading example rows
        by *slot* from the (padded, E_max, seq_len+1) buffer instead of by
        user id from the device corpus. Donated (params, opt_state) keep the
        compile-once, update-in-place behavior of the scan path."""
        return self._compute_phase(
            params, opt_state, round_idx,
            (cohort_examples, cohort_counts, keys), slot_mask, report_mask,
            corrupt, k_noise)

    def _streamed_fns(self, donate: bool) -> Tuple[Callable, Callable]:
        """(sample_jit, compute_jit), compiled once per donation policy:
        ``run`` donates (in-place state updates, two live cohort buffers);
        ``run_python`` keeps inputs alive so tests can replay states."""
        if donate not in self._streamed_jits:
            self._streamed_jits[donate] = (
                jax.jit(self._sample_body,
                        donate_argnums=(0,) if donate else ()),
                jax.jit(self._compute_body,
                        donate_argnums=(0, 1) if donate else ()))
        return self._streamed_jits[donate]

    def _stage_cohort(self, ids: np.ndarray, slot: int):
        """Host-gather one cohort's example rows from the PopulationStore
        and start their host→device transfer into buffer ``slot`` (two slots
        ping-pong so at most two staged cohorts are ever device-live — the
        one computing and the one prefetching)."""
        ex = self.store.gather(ids)
        cnt = self.store.gather_counts(ids)
        if self._cohort_sharding is not None:
            staged = (jax.device_put(ex, self._cohort_sharding),
                      jax.device_put(cnt, self._cohort_sharding))
        else:
            staged = (jax.device_put(ex), jax.device_put(cnt))
        self._inflight[slot] = staged   # overwriting frees round k−2's pair
        return staged

    def _run_streamed(self, state: EngineState, n_rounds: int, *,
                      donate: bool, prefetch: bool
                      ) -> Tuple[EngineState, Dict[str, np.ndarray]]:
        """Host-driven round loop over the two jitted bodies. With
        ``prefetch`` the loop runs one round ahead on the sampler chain:
        round k+1's cohort ids are sampled, host-gathered, and device_put
        while round k's (asynchronously dispatched) chunked compute scan is
        still in flight — the double-buffered pipeline. Without it, rounds
        stage-then-compute sequentially (the reference dispatch order);
        both orders consume identical PRNG streams and are bit-identical."""
        sample_jit, compute_jit = self._streamed_fns(donate)
        sstate = _SamplerState(state.key, state.last_round,
                               state.participation, state.round_idx)
        params, opt_state = state.params, state.opt_state

        def sample_and_stage(sstate, slot):
            sstate, (ids, slot_mask, report_mask, corrupt, keys,
                     k_noise, ridx) = sample_jit(sstate)
            # the only per-round host sync: the (padded,) id vector
            ex, cnt = self._stage_cohort(np.asarray(ids), slot)
            return sstate, (ridx, ex, cnt, slot_mask, report_mask, corrupt,
                            keys, k_noise)

        recs = []
        if prefetch:
            sstate, staged = sample_and_stage(sstate, 0)
            for r in range(n_rounds):
                params, opt_state, rec = compute_jit(params, opt_state,
                                                     *staged)
                if r + 1 < n_rounds:
                    # overlaps the compute dispatched just above
                    sstate, staged = sample_and_stage(sstate, (r + 1) % 2)
                recs.append(rec)
        else:
            for r in range(n_rounds):
                sstate, staged = sample_and_stage(sstate, r % 2)
                params, opt_state, rec = compute_jit(params, opt_state,
                                                     *staged)
                recs.append(rec)
        recs = jax.device_get(recs)
        hist = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *recs)
        self._inflight = [None, None]
        new_state = EngineState(params, opt_state, sstate.key,
                                sstate.last_round, sstate.participation,
                                sstate.round_idx)
        return new_state, hist

    def run_sampler(self, state: EngineState, n_rounds: int) -> EngineState:
        """Sampling-only loop (benchmark attribution): advance the sampler
        chain — cohort selection + population-vector updates — ``n_rounds``
        times through the same jitted :meth:`_sample_body` both backends
        use, skipping staging and compute. Consumes the round PRNG stream
        exactly as a full round would, so wall time here *is* the round's
        ``sample_s`` share. Inputs are kept alive (no donation)."""
        sample_jit, _ = self._streamed_fns(False)
        sstate = _SamplerState(state.key, state.last_round,
                               state.participation, state.round_idx)
        for _ in range(n_rounds):
            sstate, out = sample_jit(sstate)
        jax.block_until_ready(sstate)
        return EngineState(state.params, state.opt_state, sstate.key,
                           sstate.last_round, sstate.participation,
                           sstate.round_idx)

    # ------------------------------------------------------------------ entry

    def run(self, state: EngineState, n_rounds: int
            ) -> Tuple[EngineState, Dict[str, np.ndarray]]:
        """Compiled path: scan ``rounds_per_call`` rounds per jit call.
        Returns (state, history pytree of arrays with a leading (n_rounds,)
        axis — scalars per round for the training metrics, the stacked
        ``eval_fn`` output pytree under ``"eval"`` when a hook is set).

        On the streamed population backend this is the double-buffered
        host-driven loop instead (one round per compute call, cohort k+1
        staging under cohort k's compute; ``rounds_per_call`` is a no-op
        there); donation semantics are identical — the input state is
        consumed either way."""
        if n_rounds <= 0:
            return state, {}
        if self.population_backend == "streamed":
            return self._run_streamed(state, n_rounds, donate=True,
                                      prefetch=True)
        hists = []
        left = n_rounds
        while left > 0:
            k = min(self.rounds_per_call, left)
            state, h = self._run_k(k)(state)
            hists.append(jax.device_get(h))
            left -= k
        hist = jax.tree_util.tree_map(
            lambda *xs: np.concatenate(xs), *hists)
        return state, hist

    def run_python(self, state: EngineState, n_rounds: int
                   ) -> Tuple[EngineState, Dict[str, np.ndarray]]:
        """Reference path: the same round body, one jit entry per round.
        Consumes the identical PRNG stream as :meth:`run`, so cohorts,
        batches, and noise match round for round. On the streamed backend:
        the non-donating, non-prefetching (stage-then-compute) dispatch of
        the same two jitted bodies — bit-identical to :meth:`run`."""
        if n_rounds <= 0:
            return state, {}
        if self.population_backend == "streamed":
            return self._run_streamed(state, n_rounds, donate=False,
                                      prefetch=False)
        recs = []
        for _ in range(n_rounds):
            state, rec = self._one_round(state)
            recs.append(jax.device_get(rec))
        hist = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *recs)
        return state, hist
