"""Device population simulation: availability gating + Pace Steering.

The paper (§V-A) describes why production FL breaks the accountant's
uniform-sampling assumption: devices only *check in* when idle, charging and
on unmetered Wi-Fi, and Pace Steering [BEG+19] lowers a device's scheduling
priority right after it participates. Secret-sharing synthetic devices are
always available and exempt from Pace Steering — which is why the paper's
canary devices participate 1–2 orders of magnitude more than real ones
(Table 3: each synthetic device participates ≈1150 times in 2000 rounds).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np


@dataclass
class PopulationSim:
    n_users: int
    availability: float = 0.1          # P(device meets check-in criteria)
    pace_cooldown: int = 50            # rounds of lowered priority after participating
    pace_penalty: float = 0.01         # relative selection weight while cooling down
    synthetic_ids: Sequence[int] = ()  # always-available, no Pace Steering
    seed: int = 0
    _last_round: np.ndarray = field(init=False, default=None)

    def __post_init__(self):
        self._last_round = np.full(self.n_users, -(10 ** 9), np.int64)
        self._synth = np.zeros(self.n_users, bool)
        if len(self.synthetic_ids):
            self._synth[np.asarray(self.synthetic_ids)] = True
        self._rng = np.random.default_rng(self.seed)

    def checked_in(self, round_idx: int) -> np.ndarray:
        """ids of devices meeting availability criteria this round."""
        avail = self._rng.random(self.n_users) < self.availability
        avail |= self._synth                    # synthetic devices always on
        return np.nonzero(avail)[0]

    def selection_weights(self, ids: np.ndarray, round_idx: int) -> np.ndarray:
        """Pace Steering: devices that participated recently are deprioritized
        (synthetic devices exempt, per the paper's experiment setup)."""
        cooling = (round_idx - self._last_round[ids]) < self.pace_cooldown
        cooling &= ~self._synth[ids]
        w = np.where(cooling, self.pace_penalty, 1.0)
        return w / w.sum()

    def mark_participated(self, ids: np.ndarray, round_idx: int) -> None:
        self._last_round[ids] = round_idx

    def absorb_last_round(self, last_round: np.ndarray) -> None:
        """Overwrite the Pace-Steering recency vector wholesale — used to
        mirror device-resident engine state (`EngineState.last_round`) back
        into the host population after an engine run."""
        self._last_round = np.asarray(last_round, np.int64)


def participation_rates(participation: np.ndarray, synthetic: np.ndarray,
                        rounds: int):
    """(synthetic, real) mean participations *per round* from a per-device
    participation-count vector — works on both the host `PopulationSim`
    tallies and `SimEngine` state (`EngineState.participation`), which is
    how Table 3's synthetic-vs-real participation gap is measured."""
    part = np.asarray(participation, np.float64)
    synth = np.asarray(synthetic, bool)
    synth_rate = part[synth].mean() / rounds if synth.any() else 0.0
    real_rate = part[~synth].mean() / rounds if (~synth).any() else 0.0
    return synth_rate, real_rate
