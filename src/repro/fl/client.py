"""UserUpdate(k, θ) — Algorithm 1's client procedure + cohort accumulation.

E local epochs of minibatch SGD at learning rate η_c, then the model delta
Δ = θ_local − θ0 clipped to L2 norm S. Pure-JAX, jit-compiled once per
(model, batch-shape).

Two cohort-level consumers share this file:

* :func:`round_compute` — the host reference round body, and
* the simulation engine (`repro.fl.engine`), which calls
  :func:`stream_block_sums` per cohort shard.

Both accumulate the round's clipped sum **streamingly**: a ``lax.scan`` over
contiguous cohort *chunks* (``cohort_chunk`` clients vmapped per step) runs
local SGD per chunk and folds each chunk's clipped updates straight into the
canonical block partials (`repro.fl.reduction`), so peak update memory is
O(cohort_chunk · |params|) instead of the materializing O(cohort · |params|)
stack. The per-slot fold is strictly sequential (``reduction.slot_fold``
association), which makes trajectories bit-identical across every
``cohort_chunk`` dividing the canonical block size — the same
topology-invariance contract the cross-shard block tree provides one level
up. ``cohort_chunk=0`` selects the legacy materializing path (kept as the
validated reference and the benchmark baseline).

The per-slot clip→accumulate goes through
`core.clipping.clip_accumulate_tree`: the fused Pallas ``dp_clip`` kernels
by default (``clip_path="fused"``; interpret mode on CPU, compiled on TPU),
or the pytree reference (``clip_path="tree"``).
"""
from __future__ import annotations

from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ClientConfig, DPConfig
from repro.core.clipping import clip_accumulate_tree, clip_by_global_norm
from repro.fl.reduction import (CANON_BLOCKS, canon_pad, fold_blocks,
                                resolve_chunk)
from repro.models.api import Model
from repro.utils.pytree import tree_sub, tree_zeros_like


def local_sgd(model: Model, params, batches: Dict[str, jnp.ndarray],
              client: ClientConfig):
    """batches: pytree of (n_batches, B, ...) arrays. Runs E epochs of SGD."""

    def sgd_batch(p, batch):
        loss, grads = jax.value_and_grad(model.loss_fn)(p, batch)
        new_p = jax.tree_util.tree_map(
            lambda w, g: (w.astype(jnp.float32)
                          - client.lr * g.astype(jnp.float32)).astype(w.dtype),
            p, grads)
        return new_p, loss

    def epoch(p, _):
        p, losses = jax.lax.scan(sgd_batch, p, batches)
        return p, jnp.mean(losses)

    params, losses = jax.lax.scan(epoch, params, None,
                                  length=client.local_epochs)
    return params, jnp.mean(losses)


def local_delta(model: Model, params0, batches, client: ClientConfig):
    """Unclipped client delta: E local epochs, then Δ = θ_local − θ0 in f32.
    Returns (delta pytree, mean loss)."""
    params_local, loss = local_sgd(model, params0, batches, client)
    delta = tree_sub(
        jax.tree_util.tree_map(lambda l: l.astype(jnp.float32), params_local),
        jax.tree_util.tree_map(lambda l: l.astype(jnp.float32), params0))
    return delta, loss


def local_deltas(model: Model, params, stacked_batches, client: ClientConfig):
    """:func:`local_delta` vmapped over a stacked client chunk — the
    *compute* half of the streaming accumulator: the (chunk, |params|) delta
    stack is the only per-client buffer that ever materializes."""
    return jax.vmap(lambda b: local_delta(model, params, b, client))(
        stacked_batches)


def user_update(model: Model, params0, batches, client: ClientConfig,
                dp: DPConfig):
    """Returns (clipped Δ_k, pre-clip norm, was_clipped, mean loss)."""
    delta, loss = local_delta(model, params0, batches, client)
    clipped, norm, was_clipped = clip_by_global_norm(delta, dp.clip_norm)
    return clipped, norm, was_clipped, loss


def client_updates(model: Model, params, stacked_batches,
                   client: ClientConfig, dp: DPConfig):
    """Per-client :func:`user_update` vmapped over the stacked cohort —
    *unreduced*: (clipped Δ stack (C, …), norms (C,), was_clipped (C,),
    losses (C,)). This is the materializing path (O(cohort) update memory),
    kept as the validated reference; the streaming accumulator
    (:func:`stream_block_sums`) replaces it on the hot path."""
    def one(batches):
        return user_update(model, params, batches, client, dp)

    return jax.vmap(one)(stacked_batches)


# ------------------------------------------------------- streaming fold


def chunk_accumulate(acc, deltas, losses, mask, clip_norm: float, *,
                     clip_path: str = "fused", interpret=None,
                     guard_nonfinite: bool = False):
    """Fold one chunk's unclipped client deltas into the running block
    accumulator, one slot at a time.

    ``acc`` is ``(update_acc pytree f32, stats_acc (4,) f32)`` where the
    stats pack [Σ norms, Σ clipped-flags, Σ losses, Σ mask]. ``deltas`` has
    a leading (chunk,) axis, ``mask`` is the chunk's 0/1 slot mask folded
    into the clip factor (masked slots contribute exactly ±0). The fold is a
    strict left-to-right ``lax.scan`` — the canonical intra-block
    association (`reduction.slot_fold`), so splitting a block into chunks of
    any dividing size reproduces bit-identical partials.

    ``guard_nonfinite`` is the server-side corrupt-report rejection of the
    production fault model (`fl.faults`): a slot whose delta (or loss)
    carries any non-finite value is rejected *before* it can poison the
    accumulator — its mask is zeroed, so it contributes exact ±0 to both
    the clipped sum and the stat sums, exactly like a dropped/Poisson-
    excluded slot, and ``stats[3]`` ends up counting only *accepted*
    reports (the count the round's report goal is checked against)."""
    m = mask.astype(jnp.float32)

    def fold(carry, slot):
        upd, stats = carry
        delta, loss, mi = slot
        if guard_nonfinite:
            leaves = jax.tree_util.tree_leaves(delta)
            ok = jnp.all(jnp.stack(
                [jnp.all(jnp.isfinite(l)) for l in leaves]
                + [jnp.isfinite(loss)])).astype(jnp.float32)
            # zero the garbage values too: NaN·0 = NaN, so a zeroed mask
            # alone would still poison the norm/accumulator arithmetic
            delta = jax.tree_util.tree_map(
                lambda l: jnp.where(jnp.isfinite(l), l, 0.0), delta)
            loss = jnp.where(jnp.isfinite(loss), loss, 0.0)
            mi = mi * ok
        upd, norm, flag = clip_accumulate_tree(
            upd, delta, clip_norm, scale=mi, clip_path=clip_path,
            interpret=interpret)
        stats = stats + jnp.stack([norm * mi, flag * mi, loss * mi, mi])
        return (upd, stats), None

    (upd, stats), _ = jax.lax.scan(fold, acc, (deltas, losses, m))
    return upd, stats


def stream_block_sums(compute_chunk, chunk_inputs, chunk_masks, params_like,
                      clip_norm: float, *, clip_path: str = "fused",
                      interpret=None, guard_nonfinite: bool = False):
    """Streaming chunked accumulation of one cohort slice's canonical block
    partials — the engine's and the host loop's shared round-sum core.

    ``chunk_inputs`` is a pytree whose leaves carry leading axes
    ``(n_blocks, chunks_per_block, chunk, ...)`` (contiguous slots, so chunk
    boundaries nest inside block boundaries); ``chunk_masks`` is the
    matching ``(n_blocks, chunks_per_block, chunk)`` 0/1 slot mask.
    ``compute_chunk(inputs_slice) -> (delta stack (chunk, …) f32, losses
    (chunk,))`` produces one chunk's unclipped client deltas (gather + local
    SGD); each chunk is then clipped and folded into the block's running
    partial by :func:`chunk_accumulate`. A fully-masked chunk (padding past
    the realized round) skips its compute entirely via a scalar
    ``lax.cond`` — and because masked slots would have contributed exactly
    ±0, skipping is bit-identical to computing.

    Returns ``(block partial pytree with leading (n_blocks,) axis,
    (n_blocks, 4) stat partials)`` — the same contract the materializing
    block-sum path feeds into the pairwise `reduction.fold_blocks` tree.
    Peak live update memory: one accumulator + one (chunk, |params|) stack.

    ``guard_nonfinite`` threads the corrupt-report rejection into the
    per-slot fold (see :func:`chunk_accumulate`) — the engine enables it
    exactly when a `fl.faults.FaultConfig` injects non-finite updates.
    """
    zero = (tree_zeros_like(params_like, jnp.float32),
            jnp.zeros((4,), jnp.float32))
    chunk = chunk_masks.shape[-1]
    if chunk == 1 and chunk_masks.shape[1] > 1:
        # XLA simplifies away a degenerate (size-1) vmap batch dimension,
        # which changes the per-client arithmetic bitwise vs any width ≥ 2.
        # Chunk sizes ≥ 2 are prefix-consistent with each other, so pad the
        # width-1 compute with a duplicate slot and discard the copy — this
        # keeps cohort_chunk=1 inside the bit-parity family. When the block
        # size itself is 1 (chunks_per_block == 1) the dividing-chunk family
        # is the singleton {1} and every shard count runs the same width-1
        # program, so the doubled compute would buy no parity — skip it.
        inner = compute_chunk

        def compute_chunk(inputs):   # noqa: F811 — widened wrapper
            two = jax.tree_util.tree_map(
                lambda l: jnp.concatenate([l, l], axis=0), inputs)
            deltas, losses = inner(two)
            return (jax.tree_util.tree_map(lambda l: l[:1], deltas),
                    losses[:1])

    def chunk_step(acc, cinp):
        inputs, cmask = cinp

        def live(a):
            deltas, losses = compute_chunk(inputs)
            return chunk_accumulate(a, deltas, losses, cmask, clip_norm,
                                    clip_path=clip_path, interpret=interpret,
                                    guard_nonfinite=guard_nonfinite)

        return jax.lax.cond(jnp.any(cmask > 0), live, lambda a: a, acc), None

    def block_step(_, binp):
        acc, _ = jax.lax.scan(chunk_step, zero, binp)
        return None, acc

    _, (partials, stats) = jax.lax.scan(block_step, None,
                                        (chunk_inputs, chunk_masks))
    return partials, stats


# ------------------------------------------------------- host round body


def round_compute(model: Model, params, stacked_batches,
                  client: ClientConfig, dp: DPConfig, mask=None, *,
                  cohort_chunk=None, clip_path: str = "fused",
                  interpret=None):
    """Pure round body: (params, stacked client batches (C, nb, B, S)) →
    (sum of clipped updates, mean norm, frac clipped, mean loss).

    ``mask`` (optional (C,) 0/1) folds per-slot participation into the
    weighted sum — Poisson-sampled variable-size rounds keep a fixed-shape
    cohort buffer and zero out the unselected slots here, so the clipped sum
    and the per-round stats only see the clients that actually participated.

    The accumulation is the *same* canonical streaming path as the engine's
    (:func:`stream_block_sums` over the block grid of `repro.fl.reduction`):
    the cohort pads to the canonical block grid (pad slots alias slot 0's
    batches under a zero mask, so their contribution is exactly ±0) and each
    block folds ``cohort_chunk`` clients at a time — identical association,
    so given identical batches the host sum is bit-equal to the engine's.
    ``cohort_chunk=None`` auto-sizes per block; ``0`` restores the legacy
    materializing path (O(C) update memory, XLA-reduction association).

    Traceable — :func:`make_round_fn` wraps it in jit for the per-round host
    loop.
    """
    C = jax.tree_util.tree_leaves(stacked_batches)[0].shape[0]
    padded = canon_pad(C)
    blk = padded // CANON_BLOCKS
    chunk = resolve_chunk(cohort_chunk, blk, strict=False)
    if chunk == 0:
        return _round_compute_materialized(model, params, stacked_batches,
                                           client, dp, mask)
    m = (jnp.ones((C,), jnp.float32) if mask is None
         else mask.astype(jnp.float32))
    pad = padded - C
    if pad:
        stacked_batches = jax.tree_util.tree_map(
            lambda l: jnp.concatenate(
                [l, jnp.broadcast_to(l[:1], (pad,) + l.shape[1:])], axis=0),
            stacked_batches)
        m = jnp.concatenate([m, jnp.zeros((pad,), jnp.float32)])
    cpb = blk // chunk
    binp = jax.tree_util.tree_map(
        lambda l: l.reshape((CANON_BLOCKS, cpb, chunk) + l.shape[1:]),
        stacked_batches)
    partials, stats = stream_block_sums(
        lambda b: local_deltas(model, params, b, client),
        binp, m.reshape(CANON_BLOCKS, cpb, chunk), params, dp.clip_norm,
        clip_path=clip_path, interpret=interpret)
    total = jax.tree_util.tree_map(fold_blocks, partials)
    s = fold_blocks(stats)
    denom = jnp.maximum(s[3], 1.0)
    return total, s[0] / denom, s[1] / denom, s[2] / denom


def _round_compute_materialized(model: Model, params, stacked_batches,
                                client: ClientConfig, dp: DPConfig,
                                mask=None):
    """Legacy materializing round body (``cohort_chunk=0``): vmap the whole
    cohort, stack every clipped update, reduce once. O(C · |params|) peak
    memory — kept as the streaming path's validated reference and the
    benchmark baseline."""
    clipped, norms, flags, losses = client_updates(model, params,
                                                   stacked_batches, client, dp)
    if mask is None:
        total = jax.tree_util.tree_map(lambda l: jnp.sum(l, axis=0), clipped)
        return total, jnp.mean(norms), jnp.mean(flags), jnp.mean(losses)
    m = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(m), 1.0)
    total = jax.tree_util.tree_map(
        lambda l: jnp.tensordot(m, l.astype(jnp.float32), axes=1), clipped)
    return (total, jnp.sum(norms * m) / denom, jnp.sum(flags * m) / denom,
            jnp.sum(losses * m) / denom)


def make_round_fn(model: Model, client: ClientConfig, dp: DPConfig,
                  cohort_chunk=None, clip_path: str = "fused"):
    """jit-compiled :func:`round_compute` for the host-loop trainer. The
    chunk size re-resolves per traced cohort shape (the host loop's realized
    round size varies), so a fluctuating check-in pool still streams."""

    @partial(jax.jit, static_argnums=())
    def round_fn(params, stacked_batches):
        return round_compute(model, params, stacked_batches, client, dp,
                             cohort_chunk=cohort_chunk, clip_path=clip_path)

    return round_fn
