"""UserUpdate(k, θ) — Algorithm 1's client procedure.

E local epochs of minibatch SGD at learning rate η_c, then the model delta
Δ = θ_local − θ0 clipped to L2 norm S. Pure-JAX, jit-compiled once per
(model, batch-shape); the round layer vmaps it over sampled clients.
"""
from __future__ import annotations

from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ClientConfig, DPConfig
from repro.core.clipping import clip_by_global_norm
from repro.models.api import Model
from repro.utils.pytree import tree_sub


def local_sgd(model: Model, params, batches: Dict[str, jnp.ndarray],
              client: ClientConfig):
    """batches: pytree of (n_batches, B, ...) arrays. Runs E epochs of SGD."""

    def sgd_batch(p, batch):
        loss, grads = jax.value_and_grad(model.loss_fn)(p, batch)
        new_p = jax.tree_util.tree_map(
            lambda w, g: (w.astype(jnp.float32)
                          - client.lr * g.astype(jnp.float32)).astype(w.dtype),
            p, grads)
        return new_p, loss

    def epoch(p, _):
        p, losses = jax.lax.scan(sgd_batch, p, batches)
        return p, jnp.mean(losses)

    params, losses = jax.lax.scan(epoch, params, None,
                                  length=client.local_epochs)
    return params, jnp.mean(losses)


def user_update(model: Model, params0, batches, client: ClientConfig,
                dp: DPConfig):
    """Returns (clipped Δ_k, pre-clip norm, was_clipped, mean loss)."""
    params_local, loss = local_sgd(model, params0, batches, client)
    delta = tree_sub(
        jax.tree_util.tree_map(lambda l: l.astype(jnp.float32), params_local),
        jax.tree_util.tree_map(lambda l: l.astype(jnp.float32), params0))
    clipped, norm, was_clipped = clip_by_global_norm(delta, dp.clip_norm)
    return clipped, norm, was_clipped, loss


def client_updates(model: Model, params, stacked_batches,
                   client: ClientConfig, dp: DPConfig):
    """Per-client :func:`user_update` vmapped over the stacked cohort —
    *unreduced*: (clipped Δ stack (C, …), norms (C,), was_clipped (C,),
    losses (C,)). The sharded simulation engine calls this per cohort shard
    and does its own topology-invariant reduction (`repro.fl.engine`);
    :func:`round_compute` is the single-host reduce-in-place wrapper."""
    def one(batches):
        return user_update(model, params, batches, client, dp)

    return jax.vmap(one)(stacked_batches)


def round_compute(model: Model, params, stacked_batches,
                  client: ClientConfig, dp: DPConfig, mask=None):
    """Pure round body: (params, stacked client batches (C, nb, B, S)) →
    (sum of clipped updates, mean norm, frac clipped, mean loss).

    ``mask`` (optional (C,) 0/1) folds per-slot participation into the
    weighted sum — Poisson-sampled variable-size rounds keep a fixed-shape
    cohort buffer and zero out the unselected slots here, so the clipped sum
    and the per-round stats only see the clients that actually participated.

    Traceable — :func:`make_round_fn` wraps it in jit for the per-round host
    loop; the simulation engine uses :func:`client_updates` + its own
    shard-count-invariant reduction instead.
    """
    clipped, norms, flags, losses = client_updates(model, params,
                                                   stacked_batches, client, dp)
    if mask is None:
        total = jax.tree_util.tree_map(lambda l: jnp.sum(l, axis=0), clipped)
        return total, jnp.mean(norms), jnp.mean(flags), jnp.mean(losses)
    m = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(m), 1.0)
    total = jax.tree_util.tree_map(
        lambda l: jnp.tensordot(m, l.astype(jnp.float32), axes=1), clipped)
    return (total, jnp.sum(norms * m) / denom, jnp.sum(flags * m) / denom,
            jnp.sum(losses * m) / denom)


def make_round_fn(model: Model, client: ClientConfig, dp: DPConfig):
    """jit-compiled :func:`round_compute` for the host-loop trainer."""

    @partial(jax.jit, static_argnums=())
    def round_fn(params, stacked_batches):
        return round_compute(model, params, stacked_batches, client, dp)

    return round_fn
