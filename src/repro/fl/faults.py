"""Production round fault model: dropout, stragglers, corrupt reports,
over-selection with report goals (paper §III; arXiv 1710.06963 §B; arXiv
2305.18465).

A deployed fleet never delivers the simulator's happy path: devices accept a
training task and vanish (battery, network, user picks the phone up), report
after the server has already closed the round, or deliver garbage bytes. The
production protocol compensates by *over-selecting* — sampling
``ceil(target / expected_survival)`` clients so the expected survivor count
is the full target — and closing each round against a **report goal**: if
fewer than ``report_goal`` usable reports arrive, the round *aborts* (server
step skipped, nothing released, no privacy budget spent); if it commits, the
noise σ is calibrated to ``report_goal`` — never the realized survivor
count — so a lucky (or adversarially timed) round can't silently weaken the
per-round guarantee.

The model here is *seeded and stateless per round*: every slot's fate is a
pure function of ``(fault seed, round index, slot position)``, drawn
replicated on every shard. That single property carries three contracts:

* fault-on trajectories are bit-exact across the whole
  {pods} × {shards} × {chunk} × {device, streamed} parity grid (slot-level
  fates never depend on where a slot is computed);
* the fault stream is disjoint from the engine's training PRNG chain
  (``fold_in(PRNGKey(seed), round_idx)``), so turning faults *off* leaves
  the sampling/noise draws — and therefore the fault-free trajectory
  family — untouched;
* a crash-resumed run reproduces the exact fault stream with **no persisted
  fault state**: the "position" in the stream *is* the round index.

Per-slot fates:

* **dropped** — accepted the task, never reports: P = ``dropout_prob``.
* **late** — reports after the deadline: a ``straggler_prob`` fraction of
  devices draw an Exponential(``straggler_mean_delay``) report latency; the
  server closes the round at ``round_deadline``, so a straggler misses it
  with P(Exp(mean) > deadline) = exp(−deadline/mean).
* **corrupt** — the report arrives on time but the payload is non-finite
  garbage (truncated serialization, client-side OOM mid-update). The
  corruption is *injected into the update values* and caught by the
  server-side guard (`fl.client.chunk_accumulate(guard_nonfinite=True)`),
  not short-circuited — the rejection path is exercised end to end.

Dropped/late/rejected slots contribute exact ±0 to the round sum through
the same mask machinery Poisson-excluded slots use (`fl.reduction`), which
is why the fault model composes with every existing aggregation topology.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax

__all__ = ["FaultConfig", "FaultFates", "fault_fates"]


class FaultFates(NamedTuple):
    """Per-slot fates for one round — all ``(n_slots,)`` bool, replicated."""

    reported: jax.Array   # on time: neither dropped nor late
    corrupt: jax.Array    # reported, but the payload is non-finite garbage
    dropped: jax.Array    # never reports
    late: jax.Array       # reports after the round deadline


@dataclass(frozen=True)
class FaultConfig:
    """Seeded fleet fault model driving `fl.engine.SimEngine`'s
    over-selection / report-goal round protocol.

    ``report_goal=None`` derives the goal as ``ceil(goal_frac · target)``
    from the target cohort (2305.18465 closes rounds at ~90% of the target;
    the 0.8 default leaves abort headroom at simulation scale).
    ``over_select=False`` disables the compensating over-sampling (rounds
    then shrink by the fault rate — useful for forcing aborts in tests).
    """

    seed: int = 0
    dropout_prob: float = 0.0
    straggler_prob: float = 0.0
    straggler_mean_delay: float = 1.0
    round_deadline: float = 3.0
    corrupt_prob: float = 0.0
    report_goal: Optional[int] = None
    goal_frac: float = 0.8
    over_select: bool = True

    def __post_init__(self):
        for name in ("dropout_prob", "straggler_prob", "corrupt_prob"):
            v = getattr(self, name)
            if not 0.0 <= v < 1.0:
                raise ValueError(
                    f"FaultConfig.{name} must be in [0, 1), got {v!r} — a "
                    "probability of 1 means no round can ever commit")
        if self.straggler_mean_delay <= 0 or self.round_deadline <= 0:
            raise ValueError(
                "FaultConfig straggler_mean_delay and round_deadline must "
                f"be positive, got {self.straggler_mean_delay!r} / "
                f"{self.round_deadline!r}")
        if not 0.0 < self.goal_frac <= 1.0:
            raise ValueError(
                f"FaultConfig.goal_frac must be in (0, 1], got "
                f"{self.goal_frac!r}")
        if self.report_goal is not None and self.report_goal < 1:
            raise ValueError(
                f"FaultConfig.report_goal must be >= 1, got "
                f"{self.report_goal!r}")

    @property
    def late_prob(self) -> float:
        """P(a slot is a straggler *and* its report misses the deadline)."""
        return self.straggler_prob * math.exp(
            -self.round_deadline / self.straggler_mean_delay)

    @property
    def on_time_prob(self) -> float:
        return (1.0 - self.dropout_prob) * (1.0 - self.late_prob)

    @property
    def expected_survival(self) -> float:
        """P(a selected slot reports on time and passes the non-finite
        guard) — the denominator of the over-selection factor."""
        return self.on_time_prob * (1.0 - self.corrupt_prob)

    def resolve_report_goal(self, target: int) -> int:
        """Minimum usable-report count for a round to commit. σ is always
        calibrated to this number (`core.dp_fedavg.finalize_round` gets it
        as the round size), never to the realized survivor count."""
        if self.report_goal is not None:
            return self.report_goal
        return max(1, int(math.ceil(self.goal_frac * target)))

    def over_selection(self, target: int) -> int:
        """``ceil(target / expected_survival)`` — sample enough clients that
        the *expected* survivor count is the full target [1710.06963 §B]."""
        if not self.over_select:
            return target
        return int(math.ceil(target / self.expected_survival))


def fault_fates(fault_key, round_idx, n_slots: int,
                cfg: FaultConfig) -> FaultFates:
    """Draw one round's per-slot fates (pure, traceable — ``round_idx`` may
    be a traced scalar, which is how the fates live inside the engine's
    ``lax.scan`` round body).

    The uniforms are thresholded by the probabilities (monotone coupling):
    for a fixed seed, raising ``dropout_prob`` strictly grows the dropped
    set — `tests/test_accountant.py` leans on this for the ε-monotonicity
    property. A dropped slot can't also be late (it never reports at all);
    a corrupt flag only matters on a reported slot.
    """
    fkey = jax.random.fold_in(fault_key, round_idx)
    k_drop, k_strag, k_delay, k_corrupt = jax.random.split(fkey, 4)
    dropped = jax.random.uniform(k_drop, (n_slots,)) < cfg.dropout_prob
    straggler = (jax.random.uniform(k_strag, (n_slots,))
                 < cfg.straggler_prob)
    delay = cfg.straggler_mean_delay * jax.random.exponential(
        k_delay, (n_slots,))
    late = straggler & (delay > cfg.round_deadline) & ~dropped
    corrupt_draw = jax.random.uniform(k_corrupt, (n_slots,)) < cfg.corrupt_prob
    reported = ~dropped & ~late
    return FaultFates(reported=reported, corrupt=reported & corrupt_draw,
                      dropped=dropped, late=late)
