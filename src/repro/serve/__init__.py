"""Serving subsystem: continuous-batching NWP decode under live traffic.

* `repro.serve.engine.ServeEngine` — fixed-slot device-resident session
  cache, continuous batching over ``model.decode_step``, top-k suggestion
  candidates, atomic checkpoint hot-swap.
* `repro.serve.frontend` — `NwpRequest` / `SessionResult` / the FIFO queue.
* `repro.serve.reference` — the pure-Python single-request path the engine
  must match token-for-token.
* `repro.serve.sampling` — per-session keyed sampling + candidate ranking.
"""
from repro.serve.engine import ServeEngine, validate_cache_layout
from repro.serve.frontend import NwpRequest, RequestQueue, SessionResult
from repro.serve.reference import reference_generate

__all__ = ["ServeEngine", "NwpRequest", "RequestQueue", "SessionResult",
           "reference_generate", "validate_cache_layout"]
