"""Serving frontend types: session requests, results, and the FIFO queue.

A *session* is one suggestion-strip interaction: the client ships a prompt
(the text typed so far), the engine admits it into a decode slot, emits
``steps`` next-word predictions (each with ``top_k`` ranked candidates for
the strip), and the session completes.  Requests that cannot be admitted
immediately wait in the :class:`RequestQueue`; the continuous-batching
engine (`repro.serve.engine.ServeEngine`) drains it as slots free up.

Sampling is *per-session* deterministic: a session's tokens depend only on
(params, prompt, seed, temperature), never on which slot it landed in, what
else shared the batch, or when it was admitted — that is the property the
batched engine's token-for-token parity with the single-request reference
path (`repro.serve.reference`) pins down.
"""
from __future__ import annotations

import collections
import itertools
from dataclasses import dataclass, field
from typing import Deque, Optional, Tuple

import numpy as np

_SESSION_COUNTER = itertools.count()


@dataclass(frozen=True)
class NwpRequest:
    """One next-word-prediction session request.

    ``seed`` keys the session's sampling stream (required when
    ``temperature > 0``); ``ttl_ticks`` bounds how many decode ticks the
    session may occupy a slot before the engine evicts it (``None`` =
    engine default).
    """
    prompt: Tuple[int, ...]
    steps: int
    session_id: Optional[str] = None
    temperature: float = 0.0
    seed: Optional[int] = None
    top_k: Optional[int] = None
    ttl_ticks: Optional[int] = None

    def validate(self, vocab: int, engine_top_k: int) -> None:
        if self.steps < 0:
            raise ValueError(f"steps must be >= 0, got {self.steps}")
        if len(self.prompt) == 0:
            raise ValueError("prompt must be non-empty (at least BOS)")
        toks = np.asarray(self.prompt)
        if toks.min() < 0 or toks.max() >= vocab:
            raise ValueError(
                f"prompt tokens must be in [0, {vocab}), got range "
                f"[{toks.min()}, {toks.max()}]")
        if self.temperature > 0.0 and self.seed is None:
            raise ValueError(
                "temperature>0 sampling needs a per-session seed: pass "
                "NwpRequest(seed=...) so concurrent sessions draw from "
                "independent, reproducible streams")
        if self.top_k is not None and not (1 <= self.top_k <= engine_top_k):
            raise ValueError(
                f"top_k must be in [1, {engine_top_k}] (the engine's "
                f"compiled candidate width), got {self.top_k}")
        if self.ttl_ticks is not None and self.ttl_ticks < 1:
            raise ValueError(f"ttl_ticks must be >= 1, got {self.ttl_ticks}")


@dataclass
class SessionResult:
    """Completed (or evicted) session: the emitted tokens, the per-position
    top-k candidate strip, and which params version produced each token
    (``params_versions`` is how the hot-swap drill proves no session ever
    saw a mixed-checkpoint step)."""
    session_id: str
    prompt: Tuple[int, ...]
    tokens: Tuple[int, ...]
    candidates: np.ndarray            # (len(tokens), top_k) int32, ranked
    status: str                       # "done" | "evicted"
    params_versions: Tuple[int, ...]  # one entry per emitted token
    submit_tick: int
    admit_tick: int
    finish_tick: int
    latency_s: float

    @property
    def sequence(self) -> Tuple[int, ...]:
        return self.prompt + self.tokens


@dataclass
class _Session:
    """Engine-internal per-session bookkeeping (host side)."""
    request: NwpRequest
    session_id: str
    key: np.ndarray                   # (2,) uint32 — session sampling key
    submit_tick: int
    submit_time: float
    tokens: list = field(default_factory=list)
    candidates: list = field(default_factory=list)
    versions: list = field(default_factory=list)
    admit_tick: int = -1
    ticks_in_slot: int = 0


class RequestQueue:
    """FIFO admission queue. ``submit`` assigns a session id if the request
    did not carry one; the engine pops in arrival order."""

    def __init__(self):
        self._q: Deque = collections.deque()

    def __len__(self) -> int:
        return len(self._q)

    def push(self, item) -> None:
        self._q.append(item)

    def pop(self):
        return self._q.popleft()

    def peek(self):
        return self._q[0]


def new_session_id() -> str:
    return f"s{next(_SESSION_COUNTER):08d}"


def make_session_key(seed: Optional[int]) -> np.ndarray:
    """Host-side copy of ``jax.random.PRNGKey(seed)`` (zeros when the
    session is greedy-only and carries no seed)."""
    if seed is None:
        return np.zeros((2,), np.uint32)
    import jax
    return np.asarray(jax.random.PRNGKey(seed), np.uint32)
