"""Per-session sampling + suggestion-strip candidate primitives.

The batched engine and the single-request reference path both sample
through these functions, so parity is a property of the *inputs* (logits,
session key, step index, temperature) — not of who calls them.

The key schedule is the per-session fix for the correlated-sampling bug in
the old batch driver (every row at step *t* shared ``fold_in(key, t)``):
here token *t* of a session draws from ``fold_in(session_key, t)`` where
``session_key`` is that session's own key, so concurrent sessions are
independent and a session's stream is reproducible wherever it runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_tokens(logits, keys, ts, temperatures):
    """Pick one token per row. logits (B, V) f32; keys (B, 2) uint32 —
    per-row session keys; ts (B,) int32 — per-row step index folded into
    the key; temperatures (B,) f32 — rows with ``temp <= 0`` take the
    greedy argmax, the rest sample ``categorical(logits / temp)``."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def one(key, t, row, temp):
        kt = jax.random.fold_in(key, t)
        return jax.random.categorical(kt, row / temp).astype(jnp.int32)

    safe_t = jnp.where(temperatures > 0.0, temperatures, 1.0)
    sampled = jax.vmap(one)(keys, ts, logits, safe_t)
    return jnp.where(temperatures > 0.0, sampled, greedy)


def topk_ids(logits, k: int):
    """Ranked suggestion-strip candidates: (B, V) → (B, k) int32, best
    first (``lax.top_k`` breaks ties toward the lower index, matching
    ``argmax`` — candidate 0 is always the greedy token)."""
    return jax.lax.top_k(logits, k)[1].astype(jnp.int32)
