"""Pure-Python single-request reference decode path.

One session, batch width 1, an explicit Python loop: prefill the prompt,
emit token 0 from the prefill logits, then one ``decode_step`` per token.
This is the obviously-correct semantics the continuous-batching engine must
reproduce **token-for-token** — same per-session key schedule
(`repro.serve.sampling`), same candidate ranking, same hot-swap rule (a
``swaps=[(t, params_t), ...]`` entry means tokens with index ``>= t`` are
computed by ``params_t`` while the recurrent state carries over, exactly
what an in-flight session experiences when a new checkpoint is promoted
between ticks).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.serve import sampling

# jit wrappers cached per Model instance (the bound prefill/decode_step
# partials are stable attributes), so repeated reference calls recompile
# nothing
_JIT: Dict[Any, Any] = {}


def _jitted(fn):
    if fn not in _JIT:
        _JIT[fn] = jax.jit(fn)
    return _JIT[fn]


def reference_generate(model: Model, params, prompt: Sequence[int],
                       steps: int, *, temperature: float = 0.0,
                       seed: Optional[int] = None, top_k: int = 3,
                       swaps: Sequence[Tuple[int, Any]] = ()):
    """Generate ``steps`` tokens for one session.

    Returns ``(tokens, candidates)``: the emitted token ids (length
    ``steps``) and the ranked ``(steps, top_k)`` candidate ids per
    position. ``swaps`` promotes checkpoints mid-session: ``(t, p)`` means
    params ``p`` computes every token with index ``>= t`` (a swap at
    ``t = 0`` covers the prefill too — the session was admitted after the
    promotion).
    """
    if steps < 0:
        raise ValueError(f"steps must be >= 0, got {steps}")
    if temperature > 0.0 and seed is None:
        raise ValueError("temperature>0 sampling needs a session seed")
    vocab = model.cfg.vocab
    swaps = sorted(swaps, key=lambda sw: sw[0])

    def params_at(t):
        cur = params
        for at, p in swaps:
            if t >= at:
                cur = p
        return cur

    if steps == 0:
        return (), np.zeros((0, top_k), np.int32)

    key = (jnp.asarray(jax.random.PRNGKey(seed)) if seed is not None
           else jnp.zeros((2,), jnp.uint32))
    temp = jnp.full((1,), temperature, jnp.float32)
    prefill_j = _jitted(model.prefill)
    decode_j = _jitted(model.decode_step)
    sample_j = _jitted(sampling.sample_tokens)

    last, cache = prefill_j(
        params_at(0), {"tokens": jnp.asarray(prompt, jnp.int32)[None, :]})
    tokens: List[int] = []
    cands: List[np.ndarray] = []
    cur = None
    for t in range(steps):
        if t > 0:
            last, cache = decode_j(params_at(t), cur, cache)
        lg = last[:, :vocab]
        cur = sample_j(lg, key[None], jnp.full((1,), t, jnp.int32), temp)
        tokens.append(int(cur[0]))
        cands.append(np.asarray(sampling.topk_ids(lg, top_k)[0], np.int32))
    return tuple(tokens), np.stack(cands)
