"""Continuous-batching NWP serving engine with a device-resident session
cache.

The paper's artifact is a *deployed* next-word-prediction model: a DP-FedAvg
round trains server-side, gets promoted to serving, and answers suggestion-
strip queries from millions of phones. This module is that traffic path at
simulation scale:

* **Fixed-slot session cache** — the decode state for up to ``max_slots``
  concurrent sessions lives device-resident, slot-major: one row per
  session in every cache leaf (for the CIFG-LSTM that is the tiny ``(h, c)``
  recurrent pair plus a position — ~``2·d_ff`` floats per session, so
  thousands of sessions fit per chip). Admission scatters a freshly
  prefilled session into a free slot; completion/timeout frees it. The
  decode program never changes shape, so it compiles exactly once.
* **Continuous batching** — every engine tick runs ONE ``decode_step`` over
  the full slot axis. Sessions at different depths coexist in the batch;
  finished sessions hand their slot to queued requests between ticks (no
  barrier on the slowest request, the classic continuous-batching win).
* **Per-session sampling** — token *t* of a session draws from
  ``fold_in(session_key, t)`` (`repro.serve.sampling`), so results are
  independent of slot index, batch composition, and admission timing:
  the engine is **token-for-token equal to the single-request reference
  path** (`repro.serve.reference`), which is the tested contract.
* **Top-k candidates** — each emitted position carries the ranked
  ``top_k`` candidate ids for the suggestion strip (``lax.top_k`` fused
  into the tick).
* **Bucketed admission** — prefill prompt lengths are padded up to powers
  of two (the model's length-aware prefill gathers the state at the true
  length, so results are bitwise identical to the exact-length prefill):
  the admission path compiles O(log max_prompt) prefill programs instead of
  one per distinct length, which is what keeps admission p99 bounded under
  organic length mixes. Models without length-aware prefill (detected by a
  behavioral probe at construction) fall back to exact-length admission.
* **Atomic checkpoint hot-swap** — :meth:`swap_params` /
  :meth:`load_checkpoint` promote a new checkpoint between ticks: one
  host-side reference assignment, in-flight sessions keep their slots and
  state. A tick is a single jitted call closed over a single params pytree,
  so no session ever computes a step from a mix of two checkpoints; each
  emitted token records the params version that produced it
  (``SessionResult.params_versions``), which is how the hot-swap drill
  audits atomicity.

The engine requires a *continuous-batching capable* cache layout: every
``init_cache`` leaf per-row (leading dim = batch) so sessions can be
scattered/gathered by slot — see the serving contract note in
`repro.models.api`. Ring-buffer KV models (shared scalar position) are
rejected with a clear error.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.serve import sampling
from repro.serve.frontend import (NwpRequest, RequestQueue, SessionResult,
                                  _Session, make_session_key, new_session_id)
from repro.train import checkpoint as checkpoint_lib


def validate_cache_layout(model: Model, max_slots: int, max_len: int):
    """Build the probe cache and enforce the per-row serving contract.
    Returns the (zero-initialized) slot cache on success."""
    cache = model.init_cache(max_slots, max_len)
    bad = [(path, leaf.shape)
           for path, leaf in
           jax.tree_util.tree_flatten_with_path(cache)[0]
           if np.ndim(leaf) < 1 or np.shape(leaf)[0] != max_slots]
    if bad:
        detail = ", ".join(f"{jax.tree_util.keystr(p)}: shape {s}"
                           for p, s in bad)
        raise ValueError(
            f"model '{model.cfg.name}' is not continuous-batching capable: "
            f"the serving engine scatters per-session state by slot, so "
            f"every decode-cache leaf must be per-row (leading dim = "
            f"max_slots={max_slots}); offending leaves: {detail}. "
            f"Recurrent-state models (the paper's CIFG-LSTM) satisfy this; "
            f"shared ring-buffer KV caches do not (yet).")
    return cache


class ServeEngine:
    """Session-oriented continuous-batching decode loop over
    ``model.decode_step``.

    Single-threaded host driver: call :meth:`submit` to enqueue sessions,
    :meth:`step` to run one admission+decode tick (or :meth:`run` to
    drain), :meth:`pop_completed` to collect finished sessions. Not
    thread-safe — callers interleave submits/swaps between ticks, which is
    exactly what makes the hot swap atomic.
    """

    def __init__(self, model: Model, params, *, max_slots: int = 256,
                 top_k: int = 3, max_len: int = 64,
                 default_ttl_ticks: Optional[int] = None):
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        if top_k < 1 or top_k > model.cfg.vocab:
            raise ValueError(f"top_k must be in [1, vocab="
                             f"{model.cfg.vocab}], got {top_k}")
        self.model = model
        self.max_slots = max_slots
        self.top_k = top_k
        self.vocab = model.cfg.vocab
        self.default_ttl_ticks = default_ttl_ticks

        self._params = jax.tree_util.tree_map(jnp.asarray, params)
        self._params_version = 0
        self._swap_log: List[tuple] = []   # (tick, new_version)

        self._cache = validate_cache_layout(model, max_slots, max_len)
        # host-side per-slot control state, shipped to device every tick
        self._slots: List[Optional[_Session]] = [None] * max_slots
        self._cur_tok = np.zeros((max_slots,), np.int32)
        self._keys = np.zeros((max_slots, 2), np.uint32)
        self._ts = np.zeros((max_slots,), np.int32)
        self._temps = np.zeros((max_slots,), np.float32)

        self._queue = RequestQueue()
        self._completed: List[SessionResult] = []
        self._results: Dict[str, SessionResult] = {}
        self._ticks = 0          # step() calls (admission opportunities)
        self._decode_ticks = 0   # ticks that actually ran a decode batch

        vocab, K = self.vocab, self.top_k

        def _prefill(p, toks):
            last, sub = model.prefill(p, {"tokens": toks})
            return last[:, :vocab], sub

        def _prefill_len(p, toks, length):
            last, sub = model.prefill(p, {"tokens": toks, "length": length})
            return last[:, :vocab], sub

        def _admission_sample(lg, key, temp):
            tok = sampling.sample_tokens(
                lg, key[None], jnp.zeros((1,), jnp.int32), temp[None])
            return tok[0], sampling.topk_ids(lg, K)[0]

        def _admit(cache, slot, sub):
            return jax.tree_util.tree_map(
                lambda buf, row: buf.at[slot].set(row[0]), cache, sub)

        def _tick(p, cache, toks, keys, ts, temps):
            logits, cache = model.decode_step(p, toks, cache)
            lg = logits[:, :vocab]
            nxt = sampling.sample_tokens(lg, keys, ts, temps)
            return nxt, sampling.topk_ids(lg, K), cache

        self._prefill_j = jax.jit(_prefill)
        self._prefill_len_j = jax.jit(_prefill_len)
        self._admission_sample_j = jax.jit(_admission_sample)
        self._admit_j = jax.jit(_admit, donate_argnums=(0,))
        self._tick_j = jax.jit(_tick, donate_argnums=(1,))
        # admission latency per admitted session (includes the prefill jit
        # compile on a fresh *bucketed* length — the long tail bucketing
        # exists to bound); bench_serve.py reports p50/p99 from this
        self._admission_times: List[float] = []
        self._bucketed = self._probe_length_support()

    # ------------------------------------------------------------- frontend

    @property
    def params_version(self) -> int:
        return self._params_version

    @property
    def in_flight(self) -> int:
        """Sessions admitted to a slot or waiting in the queue."""
        return len(self._queue) + self.active_sessions

    @property
    def active_sessions(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def queued(self) -> int:
        return len(self._queue)

    @property
    def ticks(self) -> int:
        return self._ticks

    def submit(self, request: NwpRequest) -> str:
        """Validate + enqueue a session; returns its session id. A
        ``steps=0`` request completes immediately with exactly the prompt
        (no slot, no decode — the suggestion strip asked for nothing)."""
        request.validate(self.vocab, self.top_k)
        sid = request.session_id or new_session_id()
        if sid in self._results or any(
                s is not None and s.session_id == sid for s in self._slots):
            raise ValueError(f"duplicate session_id {sid!r}")
        sess = _Session(request=request, session_id=sid,
                        key=make_session_key(request.seed),
                        submit_tick=self._ticks,
                        submit_time=time.perf_counter())
        if request.steps == 0:
            sess.admit_tick = self._ticks
            self._finalize(sess, "done", slot=None)
            return sid
        self._queue.push(sess)
        return sid

    def pop_completed(self) -> List[SessionResult]:
        out, self._completed = self._completed, []
        return out

    def result(self, session_id: str) -> SessionResult:
        return self._results[session_id]

    # ------------------------------------------------------------- hot swap

    def swap_params(self, new_params) -> int:
        """Atomically promote ``new_params`` for every *subsequent* prefill
        and decode tick. In-flight sessions keep their slots and recurrent
        state; tokens already emitted keep their version label. Returns the
        new params version."""
        self._params = jax.tree_util.tree_map(jnp.asarray, new_params)
        self._params_version += 1
        self._swap_log.append((self._ticks, self._params_version))
        return self._params_version

    def load_checkpoint(self, path) -> int:
        """Hot-swap from a checkpoint file (the DP-trained round promoted
        to serving): fully loaded + converted host-side, then published in
        one :meth:`swap_params` call."""
        params, _meta = checkpoint_lib.load(path)
        return self.swap_params(params)

    # ------------------------------------------------------------- the loop

    def step(self) -> bool:
        """One engine tick: admit from the queue into free slots, then run
        one batched decode step over all slots. Returns True while there is
        work in flight."""
        self._ticks += 1
        for slot in range(self.max_slots):
            if not len(self._queue):
                break
            if self._slots[slot] is None:
                self._admit(slot, self._queue.pop())
        if self.active_sessions == 0:
            return len(self._queue) > 0
        self._decode_ticks += 1
        nxt, cands, self._cache = self._tick_j(
            self._params, self._cache,
            jnp.asarray(self._cur_tok), jnp.asarray(self._keys),
            jnp.asarray(self._ts), jnp.asarray(self._temps))
        nxt = np.asarray(nxt)
        cands = np.asarray(cands)
        for slot, sess in enumerate(self._slots):
            if sess is None:
                continue
            self._record_token(sess, int(nxt[slot]), cands[slot])
            self._cur_tok[slot] = nxt[slot]
            self._ts[slot] += 1
            sess.ticks_in_slot += 1
            if len(sess.tokens) >= sess.request.steps:
                self._finalize(sess, "done", slot=slot)
            elif self._ttl(sess) and sess.ticks_in_slot >= self._ttl(sess):
                self._finalize(sess, "evicted", slot=slot)
        return self.in_flight > 0

    def run(self, max_ticks: int = 100_000) -> Dict[str, SessionResult]:
        """Drain queue + slots; returns {session_id: result} for every
        session finished during this call."""
        before = dict(self._results)
        for _ in range(max_ticks):
            if not self.step():
                break
        else:
            raise RuntimeError(f"run() did not drain in {max_ticks} ticks")
        return {k: v for k, v in self._results.items() if k not in before}

    # ------------------------------------------------------------ internals

    def _ttl(self, sess: _Session) -> Optional[int]:
        ttl = sess.request.ttl_ticks
        return ttl if ttl is not None else self.default_ttl_ticks

    def _probe_length_support(self) -> bool:
        """Behavioral probe for the length-aware prefill contract: a model
        supports bucket-padded admission iff prefilling ``[t]`` unpadded and
        ``[t, 0]`` with ``length=[1]`` agree *bitwise* (logits and every
        cache leaf). A model that rejects — or silently ignores — the
        ``"length"`` batch key fails the probe, and admission falls back to
        exact-length prefills (one jit compile per distinct prompt
        length)."""
        try:
            toks = jnp.zeros((1, 1), jnp.int32)
            ref_lg, ref_sub = self._prefill_j(self._params, toks)
            lg, sub = self._prefill_len_j(
                self._params, jnp.zeros((1, 2), jnp.int32),
                jnp.ones((1,), jnp.int32))
        except Exception:
            return False
        ref_leaves = jax.tree_util.tree_leaves((ref_lg, ref_sub))
        leaves = jax.tree_util.tree_leaves((lg, sub))
        return len(ref_leaves) == len(leaves) and all(
            a.shape == b.shape and bool(jnp.all(a == b))
            for a, b in zip(ref_leaves, leaves))

    @property
    def admission_times_s(self) -> tuple:
        """Wall-clock seconds per admission (prefill + first-token sample +
        slot scatter, synced on the emitted token), in admission order."""
        return tuple(self._admission_times)

    @property
    def bucketed_admission(self) -> bool:
        """True when the construction-time probe validated the model's
        length-aware prefill and admissions pad to power-of-two buckets."""
        return self._bucketed

    def _admit(self, slot: int, sess: _Session) -> None:
        """Prefill the prompt (current params), scatter the session state
        into ``slot``, and emit token 0 from the prefill logits. Prompt
        lengths are bucketed to powers of two (right-padded, with the true
        length gathered inside the model's length-aware prefill) so a fresh
        length only compiles when it crosses a power of two — token-for-
        token identical to the exact-length prefill, which is what
        :meth:`_probe_length_support` guarantees up front."""
        t0 = time.perf_counter()
        raw = np.asarray(sess.request.prompt, np.int32)
        L = int(raw.shape[0])
        if self._bucketed and L > 1:
            Lp = 1 << (L - 1).bit_length()
            padded = np.zeros((1, Lp), np.int32)
            padded[0, :L] = raw
            lg, sub = self._prefill_len_j(self._params, jnp.asarray(padded),
                                          jnp.full((1,), L, jnp.int32))
        else:
            lg, sub = self._prefill_j(self._params,
                                      jnp.asarray(raw)[None, :])
        tok0, cands0 = self._admission_sample_j(
            lg, jnp.asarray(sess.key),
            jnp.asarray(sess.request.temperature, jnp.float32))
        self._cache = self._admit_j(self._cache, jnp.asarray(slot), sub)
        sess.admit_tick = self._ticks
        self._slots[slot] = sess
        self._keys[slot] = sess.key
        self._temps[slot] = sess.request.temperature
        self._record_token(sess, int(tok0), np.asarray(cands0))
        self._admission_times.append(time.perf_counter() - t0)
        self._cur_tok[slot] = sess.tokens[-1]
        self._ts[slot] = 1
        if len(sess.tokens) >= sess.request.steps:
            self._finalize(sess, "done", slot=slot)

    def _record_token(self, sess: _Session, tok: int, cands) -> None:
        sess.tokens.append(tok)
        sess.candidates.append(np.asarray(cands, np.int32))
        sess.versions.append(self._params_version)

    def _finalize(self, sess: _Session, status: str,
                  slot: Optional[int]) -> None:
        if slot is not None:
            self._slots[slot] = None
            self._temps[slot] = 0.0
            self._ts[slot] = 0
        k = sess.request.top_k or self.top_k
        cands = (np.stack(sess.candidates)[:, :k] if sess.candidates
                 else np.zeros((0, k), np.int32))
        res = SessionResult(
            session_id=sess.session_id,
            prompt=tuple(int(t) for t in sess.request.prompt),
            tokens=tuple(sess.tokens),
            candidates=cands,
            status=status,
            params_versions=tuple(sess.versions),
            submit_tick=sess.submit_tick,
            admit_tick=sess.admit_tick,
            finish_tick=self._ticks,
            latency_s=time.perf_counter() - sess.submit_time)
        self._results[sess.session_id] = res
        self._completed.append(res)
