"""Katz-smoothed backoff n-gram LM — the paper's baseline (§III).

The production baseline is a Katz-smoothed Bayesian-interpolated n-gram FST
augmented with a user-history LM; we implement the core Katz backoff trigram
(absolute discounting variant) which is the dominant component, and an
optional per-user history unigram interpolation to mirror the "personalized
components" note under Table 2.
"""
from __future__ import annotations

from collections import Counter, defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class KatzTrigramLM:
    def __init__(self, vocab_size: int, discount: float = 0.4):
        self.vocab_size = vocab_size
        self.discount = discount
        self.uni = Counter()
        self.bi: Dict[int, Counter] = defaultdict(Counter)
        self.tri: Dict[Tuple[int, int], Counter] = defaultdict(Counter)
        self.total = 0

    def fit(self, sentences: Sequence[Sequence[int]]) -> "KatzTrigramLM":
        for s in sentences:
            for i, w in enumerate(s):
                self.uni[w] += 1
                self.total += 1
                if i >= 1:
                    self.bi[s[i - 1]][w] += 1
                if i >= 2:
                    self.tri[(s[i - 2], s[i - 1])][w] += 1
        return self

    def _backoff_scores(self, counts: Counter, lower: Dict[int, float],
                        d: float) -> Dict[int, float]:
        total = sum(counts.values())
        if total == 0:
            return dict(lower)
        scores = {w: max(c - d, 0.0) / total for w, c in counts.items()}
        mass = d * len(counts) / total
        z = sum(p for w, p in lower.items() if w not in counts) or 1e-12
        for w, p in lower.items():
            if w not in scores:
                scores[w] = mass * p / z
        return scores

    def next_word_scores(self, context: Sequence[int],
                         history: Optional[Counter] = None,
                         history_weight: float = 0.1) -> Dict[int, float]:
        uni_p = {w: c / max(self.total, 1) for w, c in self.uni.items()}
        bi_p = (self._backoff_scores(self.bi.get(context[-1], Counter()),
                                     uni_p, self.discount)
                if context else uni_p)
        if len(context) >= 2:
            key = (context[-2], context[-1])
            scores = self._backoff_scores(self.tri.get(key, Counter()),
                                          bi_p, self.discount)
        else:
            scores = bi_p
        if history:
            htot = sum(history.values())
            out = {w: (1 - history_weight) * p for w, p in scores.items()}
            for w, c in history.items():
                out[w] = out.get(w, 0.0) + history_weight * c / htot
            return out
        return scores

    def topk(self, context: Sequence[int], k: int = 3,
             history: Optional[Counter] = None) -> List[int]:
        scores = self.next_word_scores(context, history)
        return [w for w, _ in sorted(scores.items(),
                                     key=lambda x: -x[1])[:k]]


def recall_at_k(lm: KatzTrigramLM, sentences: Sequence[Sequence[int]],
                k: int = 1) -> float:
    """top-k recall: correct next-word predictions / total words (§III-A)."""
    hit, total = 0, 0
    for s in sentences:
        for i in range(1, len(s)):
            pred = lm.topk(s[max(0, i - 2):i], k)
            hit += int(s[i] in pred)
            total += 1
    return hit / max(total, 1)
