"""Fixed-vocabulary word-level tokenizer.

The paper (§I, §V-B) uses a *fixed* 10k word vocabulary as one of its
privacy measures — the vocabulary is not derived from private user data, so
no private information can leak through vocabulary membership. We mirror
that: the vocab is fixed up front (synthetic word list), OOV maps to UNK.
"""
from __future__ import annotations

from typing import Iterable, List

PAD, UNK, BOS, EOS = 0, 1, 2, 3
N_SPECIAL = 4


class Tokenizer:
    def __init__(self, vocab_size: int):
        self.vocab_size = vocab_size
        self._words = ["<pad>", "<unk>", "<s>", "</s>"] + [
            f"w{i}" for i in range(vocab_size - N_SPECIAL)]
        self._ids = {w: i for i, w in enumerate(self._words)}

    def encode_word(self, w: str) -> int:
        return self._ids.get(w, UNK)

    def encode(self, words: Iterable[str]) -> List[int]:
        return [self.encode_word(w) for w in words]

    def decode(self, ids: Iterable[int]) -> List[str]:
        return [self._words[i] if 0 <= i < self.vocab_size else "<unk>"
                for i in ids]
