"""Synthetic training corpus with learnable structure.

Stand-in for the paper's Stack Overflow (tuning) and on-device Spanish
(production) corpora, which are unavailable offline. Sentences are random
walks over a sparse Zipf-weighted bigram graph, so a trained LM can beat the
unigram baseline by a wide margin (the signal the recall benchmark needs),
while word marginals stay Zipfian like natural text.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from repro.data.tokenizer import BOS, EOS, N_SPECIAL


@dataclass
class BigramCorpus:
    vocab_size: int
    branching: int = 8         # successors per word
    zipf_a: float = 1.3
    n_topics: int = 1          # >1: per-sentence latent topic switches the
    seed: int = 0              # transition table — structure an n-gram LM
                               # cannot condition on, but a recurrent model
                               # can infer from the sentence prefix (this is
                               # what lets the NWP model beat the FST
                               # baseline, mirroring the paper's Table 2)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        n = self.vocab_size - N_SPECIAL
        # Zipf marginals over real words
        ranks = np.arange(1, n + 1, dtype=np.float64)
        self.unigram = ranks ** (-self.zipf_a)
        self.unigram /= self.unigram.sum()
        # per-topic sparse successor sets
        self.succ = rng.choice(n, size=(self.n_topics, n, self.branching),
                               replace=True, p=self.unigram)
        self.succ_p = rng.dirichlet(np.full(self.branching, 0.25),
                                    size=(self.n_topics, n))

    def sample_sentence(self, rng: np.random.Generator,
                        min_len: int = 4, max_len: int = 12) -> List[int]:
        n = self.vocab_size - N_SPECIAL
        t = int(rng.integers(self.n_topics))
        length = int(rng.integers(min_len, max_len + 1))
        w = int(rng.choice(n, p=self.unigram))
        out = [BOS, w + N_SPECIAL]
        for _ in range(length - 1):
            j = int(rng.choice(self.branching, p=self.succ_p[t, w]))
            w = int(self.succ[t, w, j])
            out.append(w + N_SPECIAL)
        out.append(EOS)
        return out

    def sample_sentences(self, n_sentences: int, seed: int) -> List[List[int]]:
        rng = np.random.default_rng(seed)
        return [self.sample_sentence(rng) for _ in range(n_sentences)]

    def bigram_topk(self, prev_token: int, k: int = 3,
                    topic: int = 0) -> List[int]:
        """Oracle top-k successors (upper bound for recall benchmarks)."""
        if prev_token < N_SPECIAL:
            top = np.argsort(-self.unigram)[:k]
            return [int(t) + N_SPECIAL for t in top]
        w = prev_token - N_SPECIAL
        order = np.argsort(-self.succ_p[topic, w])[:k]
        return [int(self.succ[topic, w, j]) + N_SPECIAL for j in order]
