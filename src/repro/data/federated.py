"""User-sharded federated dataset with canary injection.

Mirrors the paper's setup (§IV-A): real devices hold sentences from the
corpus; *secret-sharing synthetic devices* hold ``n_e`` copies of their
canary plus ``(200 − n_e)`` public-corpus sentences. Per-user example caps
(one of the paper's multifaceted privacy measures) are enforced here.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.secret_sharer import Canary
from repro.data.corpus import BigramCorpus
from repro.data.tokenizer import PAD

USER_SENTENCES = 200  # paper: synthetic devices hold 200 examples total


def sentences_to_examples(sentences: Sequence[Sequence[int]], seq_len: int,
                          max_examples: Optional[int] = None) -> np.ndarray:
    """Pack sentences into fixed (n, seq_len+1) windows (inputs+shifted labels
    share the window; PAD-masked loss). One sentence per window."""
    if max_examples is not None and max_examples < 0:
        raise ValueError(f"max_examples must be >= 0, got {max_examples}")
    rows = []
    for s in sentences:
        # an explicit cap of 0 means zero examples, not "no cap"
        if max_examples is not None and len(rows) >= max_examples:
            break
        s = list(s)[: seq_len + 1]
        rows.append(s + [PAD] * (seq_len + 1 - len(s)))
    if not rows:
        return np.zeros((0, seq_len + 1), np.int32)
    return np.asarray(rows, np.int32)


def examples_to_batch(ex: np.ndarray) -> Dict[str, np.ndarray]:
    tokens = ex[:, :-1]
    labels = ex[:, 1:]
    mask = (labels != PAD).astype(np.float32)
    return {"tokens": tokens, "labels": labels, "mask": mask}


@dataclass
class UserShard:
    user_id: int
    examples: np.ndarray          # (n, seq_len+1) int32
    is_synthetic: bool = False    # secret-sharing device?
    canary: Optional[Canary] = None


@dataclass
class FederatedDataset:
    corpus: BigramCorpus
    n_users: int
    seq_len: int = 16
    sentences_per_user: int = 40
    max_examples_per_user: int = 200  # the paper's per-user cap
    seed: int = 0
    users: List[UserShard] = field(default_factory=list)

    def __post_init__(self):
        for uid in range(self.n_users):
            sents = self.corpus.sample_sentences(
                min(self.sentences_per_user, self.max_examples_per_user),
                seed=self.seed * 1_000_003 + uid)
            self.users.append(UserShard(
                uid, sentences_to_examples(sents, self.seq_len,
                                           self.max_examples_per_user)))

    def inject_canaries(self, canaries: Sequence[Canary]) -> List[UserShard]:
        """Create the paper's secret-sharing synthetic devices: for each
        canary, n_u devices each holding n_e canary copies + (200−n_e) public
        sentences. Appends them to the population; returns them.

        Canaries must have pairwise-distinct 2-word prefixes — duplicates
        included (injecting the same canary twice would silently double its
        n_u). Beam-search extraction conditions on the prefix;
        `make_canaries` already guarantees distinctness, hand-built lists
        are validated here."""
        prefixes = [c.prefix for c in canaries]
        if len(set(prefixes)) != len(prefixes):
            raise ValueError("injected canaries share a beam-search prefix "
                             "(or repeat a canary — n_u controls device "
                             "count); redraw them (see make_canaries)")
        synthetic = []
        next_id = len(self.users)
        for ci, c in enumerate(canaries):
            for u in range(c.n_u):
                n_e = min(c.n_e, USER_SENTENCES)
                pub = self.corpus.sample_sentences(
                    USER_SENTENCES - n_e,
                    seed=777_000_000 + ci * 1_000 + u)
                sents = [list(c.tokens)] * n_e + pub
                shard = UserShard(next_id,
                                  sentences_to_examples(sents, self.seq_len,
                                                        USER_SENTENCES),
                                  is_synthetic=True, canary=c)
                self.users.append(shard)
                synthetic.append(shard)
                next_id += 1
        return synthetic

    def canaries(self) -> List[Canary]:
        """Distinct injected canaries, in injection order — index-aligned
        with the (K,) outputs of `repro.core.secret_sharer.canary_eval_fn`
        built from this list."""
        return list(dict.fromkeys(
            u.canary for u in self.users if u.canary is not None))

    def user_batches(self, user_id: int, batch_size: int,
                     rng: np.random.Generator) -> List[Dict[str, np.ndarray]]:
        """Split a user's (shuffled) examples into size-B batches (last batch
        padded by repetition so shapes stay static for jit)."""
        ex = self.users[user_id].examples
        perm = rng.permutation(ex.shape[0])
        ex = ex[perm]
        n = ex.shape[0]
        batches = []
        for i in range(0, n, batch_size):
            chunk = ex[i:i + batch_size]
            if chunk.shape[0] < batch_size:
                reps = np.resize(np.arange(chunk.shape[0]), batch_size)
                chunk = chunk[reps]
            batches.append(examples_to_batch(chunk))
        return batches

    def to_device_arrays(self, max_examples: Optional[int] = None
                         ) -> Dict[str, np.ndarray]:
        """Pack the whole population into fixed-shape arrays for the compiled
        simulation engine (`repro.fl.engine`):

        * ``examples`` — (n_users, E_max, seq_len+1) int32. Users with fewer
          than E_max examples are padded by *tiling* their real examples, so
          every slot holds a valid example regardless of the index used.
        * ``counts`` — (n_users,) int32 true example counts (the engine draws
          uniform indices in [0, counts[u]) so tiled padding never skews the
          per-example distribution).
        * ``synthetic`` — (n_users,) bool secret-sharer mask (always
          available, exempt from Pace Steering).
        """
        n = len(self.users)
        empty = [u.user_id for u in self.users if u.examples.shape[0] == 0]
        if empty:
            raise ValueError(
                f"users {empty[:5]} hold zero examples — tiling an empty "
                "shard would silently serve garbage (np.resize on an empty "
                "range tiles nothing); give them data or drop them")
        emax = (max_examples if max_examples is not None
                else max(u.examples.shape[0] for u in self.users))
        if emax < 1:
            raise ValueError(f"max_examples must be >= 1 for the padded "
                             f"corpus tensor, got {max_examples}")
        ex = np.zeros((n, emax, self.seq_len + 1), np.int32)
        counts = np.zeros((n,), np.int32)
        synth = np.zeros((n,), bool)
        for i, u in enumerate(self.users):
            c = min(u.examples.shape[0], emax)
            ex[i] = u.examples[np.resize(np.arange(c), emax)]
            counts[i] = c
            synth[i] = u.is_synthetic
        return {"examples": ex, "counts": counts, "synthetic": synth}

    def user_tensor(self, user_id: int, batch_size: int, n_batches: int,
                    rng: np.random.Generator) -> Dict[str, np.ndarray]:
        """Fixed-shape (n_batches, B, S) stack for the vmapped/jit round path;
        examples are tiled if the user has fewer than n_batches·B."""
        ex = self.users[user_id].examples
        if ex.shape[0] == 0:
            raise ValueError(
                f"user {user_id} holds zero examples — cannot tile an empty "
                "shard into a fixed-shape client tensor (np.resize on an "
                "empty range tiles garbage); give the user data or exclude "
                "it from sampling")
        need = n_batches * batch_size
        idx = rng.permutation(np.resize(np.arange(ex.shape[0]), need))
        ex = ex[idx].reshape(n_batches, batch_size, -1)
        out = {"tokens": ex[:, :, :-1], "labels": ex[:, :, 1:]}
        out["mask"] = (out["labels"] != PAD).astype(np.float32)
        return out


def held_out_batch(corpus: BigramCorpus, n: int, seq_len: int,
                   seed: int = 999) -> Dict[str, np.ndarray]:
    ex = sentences_to_examples(corpus.sample_sentences(n, seed), seq_len)
    return examples_to_batch(ex)
