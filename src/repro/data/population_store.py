"""Host-resident population corpus behind a per-round cohort gather.

``FederatedDataset.to_device_arrays()`` materializes the *whole* padded
corpus on device — fine at 10³ users, a hard wall at 10⁶–10⁷, and nothing
like the production fleet the paper trains on, where the server never holds
more than the sampled cohort's data per round. A :class:`PopulationStore`
keeps the corpus on the host (RAM or memory-mapped disk shards) and serves
exactly one cohort's worth of examples per round to the streamed engine
backend (`repro.fl.engine.SimEngine(population_backend="streamed")`).

The stored representation is deliberately *identical* to the device tensor
the engine's device backend gathers from:

* ``examples`` — (N, E_max, seq_len+1) int32, each user's real examples
  **tiled** to E_max so every slot holds a valid example;
* ``counts`` — (N,) int32 true example counts (the engine draws uniform
  indices in ``[0, counts[u])`` so tiling never skews the distribution);
* ``synthetic`` — (N,) bool secret-sharer mask.

Because the values a store serves for user ``u`` are bit-identical to row
``u`` of the device-resident tensor, the streamed backend's trajectories are
bit-exact against the device backend — the headline parity contract of
``tests/test_engine_streamed.py``.

Three implementations:

* :class:`InMemoryPopulationStore` — host numpy arrays (tests, small runs);
* :class:`MmapPopulationStore` — an on-disk directory of fixed-size user
  shards (``examples-00000-of-00004.npy`` …) opened with
  ``np.load(mmap_mode="r")``, so the OS pages in only the users a round
  actually touches. Written by :func:`write_population_store` /
  ``tools/build_corpus.py``;
* :class:`ReplicatedPopulationStore` — an O(1)-memory view tiling a base
  store to N users (``uid → uid % base.n_users``), the population-sweep
  tool for benchmarking 10⁶–10⁷-user fleets without 10-GB corpus builds.

The small per-user vectors (``counts``, ``synthetic``) always live fully in
host RAM — 5 bytes/user, 5 MB at 10⁶ — only the O(N·E_max·seq_len) example
payload is sharded/mapped/virtualized.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Optional, Union

import numpy as np

STORE_META = "meta.json"
STORE_VERSION = 1
DEFAULT_SHARD_USERS = 4096


def _validate_arrays(examples: np.ndarray, counts: np.ndarray,
                     synthetic: np.ndarray) -> None:
    if examples.ndim != 3:
        raise ValueError(f"examples must be (N, E_max, seq_len+1), got "
                         f"shape {examples.shape}")
    n = examples.shape[0]
    if counts.shape != (n,) or synthetic.shape != (n,):
        raise ValueError(
            f"counts {counts.shape} / synthetic {synthetic.shape} must both "
            f"be ({n},) to match examples {examples.shape}")
    if n and int(counts.min()) < 1:
        empty = np.nonzero(np.asarray(counts) < 1)[0][:5]
        raise ValueError(
            f"population store: users {empty.tolist()} have no examples — "
            "every user must hold >= 1 example (the engine draws indices in "
            "[0, counts[u]) and tiling an empty shard is undefined); drop "
            "them upstream or give them data")


class PopulationStore:
    """Read-only host-side population corpus: per-user tiled example rows
    plus the small per-user vectors. Subclasses implement :meth:`gather`."""

    n_users: int
    emax: int          # examples per user after tiling (E_max)
    row_len: int       # seq_len + 1 (window width incl. shifted label)
    counts: np.ndarray     # (N,) int32
    synthetic: np.ndarray  # (N,) bool

    def gather(self, ids) -> np.ndarray:
        """(len(ids), E_max, seq_len+1) int32 tiled example rows for the
        given user ids (any order, duplicates fine — a padded cohort aliases
        slot 0)."""
        raise NotImplementedError

    def gather_counts(self, ids) -> np.ndarray:
        return np.ascontiguousarray(self.counts[np.asarray(ids, np.int64)],
                                    dtype=np.int32)

    def device_arrays(self) -> Dict[str, np.ndarray]:
        """Materialize the whole population as the engine's device-backend
        dict — the compatibility escape hatch (and the round-trip test
        oracle). O(N·E_max·seq_len) host memory: only call at small N."""
        return {"examples": self.gather(np.arange(self.n_users)),
                "counts": np.asarray(self.counts, np.int32),
                "synthetic": np.asarray(self.synthetic, bool)}

    # ------------------------------------------------------------- stats
    @property
    def nbytes_per_user(self) -> int:
        return self.emax * self.row_len * 4

    def _check_ids(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        if ids.size and (ids.min() < 0 or ids.max() >= self.n_users):
            raise IndexError(
                f"user ids out of range [0, {self.n_users}): "
                f"[{ids.min()}, {ids.max()}]")
        return ids


class InMemoryPopulationStore(PopulationStore):
    """Population corpus fully in host RAM — the test/small-run path and the
    base payload the replicated/mmap stores are built from."""

    def __init__(self, examples: np.ndarray, counts: np.ndarray,
                 synthetic: np.ndarray):
        examples = np.asarray(examples, np.int32)
        counts = np.asarray(counts, np.int32)
        synthetic = np.asarray(synthetic, bool)
        _validate_arrays(examples, counts, synthetic)
        self.examples = examples
        self.counts = counts
        self.synthetic = synthetic
        self.n_users = int(examples.shape[0])
        self.emax = int(examples.shape[1])
        self.row_len = int(examples.shape[2])

    @classmethod
    def from_arrays(cls, data: Dict[str, np.ndarray]
                    ) -> "InMemoryPopulationStore":
        """From a ``FederatedDataset.to_device_arrays()``-style dict."""
        return cls(data["examples"], data["counts"], data["synthetic"])

    @classmethod
    def from_dataset(cls, dataset, max_examples: Optional[int] = None
                     ) -> "InMemoryPopulationStore":
        """From a ``FederatedDataset`` — same tiling as
        ``to_device_arrays`` so the two representations are bit-identical."""
        return cls.from_arrays(dataset.to_device_arrays(max_examples))

    def gather(self, ids) -> np.ndarray:
        return np.ascontiguousarray(self.examples[self._check_ids(ids)])


class ReplicatedPopulationStore(PopulationStore):
    """O(1)-memory N-user view over a base store: ``uid → uid % base_n``.

    The population-scale benchmarking tool: a 10⁶-user fleet with realistic
    per-user payloads, no 10-GB corpus build, no disk. Only the small
    per-user vectors are physically tiled (5 bytes/user). Secret-sharer
    semantics do not survive replication (a canary's n_u multiplies), so
    this is a throughput/memory instrument, not a measurement population.
    """

    def __init__(self, base: PopulationStore, n_users: int):
        if n_users < base.n_users:
            raise ValueError(f"n_users={n_users} must be >= the base "
                             f"store's {base.n_users}")
        self.base = base
        self.n_users = int(n_users)
        self.emax = base.emax
        self.row_len = base.row_len
        reps = -(-self.n_users // base.n_users)
        self.counts = np.tile(base.counts, reps)[: self.n_users]
        self.synthetic = np.tile(base.synthetic, reps)[: self.n_users]

    def gather(self, ids) -> np.ndarray:
        return self.base.gather(self._check_ids(ids) % self.base.n_users)


class MmapPopulationStore(PopulationStore):
    """On-disk population store: ``meta.json`` + ``counts.npy`` +
    ``synthetic.npy`` + fixed-size user shards
    ``examples-00000-of-00004.npy``, each a (shard_users, E_max, seq_len+1)
    int32 ``.npy`` opened lazily with ``np.load(mmap_mode="r")`` — the OS
    pages in only the rows a cohort gather touches, so host RSS is
    O(touched users), not O(N)."""

    def __init__(self, path: Union[str, Path]):
        self.path = Path(path)
        meta_path = self.path / STORE_META
        if not meta_path.is_file():
            raise FileNotFoundError(
                f"{self.path} is not a population store (no {STORE_META}); "
                "build one with tools/build_corpus.py or "
                "write_population_store()")
        self.meta = json.loads(meta_path.read_text())
        if self.meta.get("version") != STORE_VERSION:
            raise ValueError(f"population store version "
                             f"{self.meta.get('version')} != reader version "
                             f"{STORE_VERSION} ({meta_path})")
        self.n_users = int(self.meta["n_users"])
        self.emax = int(self.meta["emax"])
        self.row_len = int(self.meta["row_len"])
        self.shard_users = int(self.meta["shard_users"])
        self.n_shards = int(self.meta["n_shards"])
        self.counts = np.load(self.path / "counts.npy")
        self.synthetic = np.load(self.path / "synthetic.npy")
        _expect = -(-self.n_users // self.shard_users)
        if self.n_shards != _expect:
            raise ValueError(
                f"corrupt store: n_shards={self.n_shards} but "
                f"{self.n_users} users / {self.shard_users} per shard "
                f"needs {_expect}")
        self._shards: Dict[int, np.ndarray] = {}

    def shard_file(self, s: int) -> Path:
        return self.path / (f"examples-{s:05d}-of-{self.n_shards:05d}.npy")

    def _shard(self, s: int) -> np.ndarray:
        if s not in self._shards:
            self._shards[s] = np.load(self.shard_file(s), mmap_mode="r")
        return self._shards[s]

    def gather(self, ids) -> np.ndarray:
        ids = self._check_ids(ids)
        out = np.empty((ids.shape[0], self.emax, self.row_len), np.int32)
        shard_of = ids // self.shard_users
        for s in np.unique(shard_of):
            sel = shard_of == s
            out[sel] = self._shard(int(s))[ids[sel] - s * self.shard_users]
        return out


def write_population_store(path: Union[str, Path], store: PopulationStore,
                           shard_users: int = DEFAULT_SHARD_USERS,
                           seq_len: Optional[int] = None) -> Path:
    """Serialize any :class:`PopulationStore` (or in-memory arrays wrapped
    in one) to the sharded mmap directory format. Streams one shard at a
    time through :meth:`PopulationStore.gather`, so writing a replicated
    10⁶-user store needs O(shard) host memory."""
    if shard_users < 1:
        raise ValueError(f"shard_users must be >= 1, got {shard_users}")
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    n = store.n_users
    n_shards = -(-n // shard_users)
    for s in range(n_shards):
        lo, hi = s * shard_users, min((s + 1) * shard_users, n)
        block = store.gather(np.arange(lo, hi))
        np.save(path / f"examples-{s:05d}-of-{n_shards:05d}.npy", block)
    np.save(path / "counts.npy", np.asarray(store.counts, np.int32))
    np.save(path / "synthetic.npy", np.asarray(store.synthetic, bool))
    meta = {"version": STORE_VERSION, "n_users": n, "emax": store.emax,
            "row_len": store.row_len,
            "seq_len": int(seq_len if seq_len is not None
                           else store.row_len - 1),
            "shard_users": int(shard_users), "n_shards": n_shards,
            "dtype": "int32"}
    (path / STORE_META).write_text(json.dumps(meta, indent=1))
    return path


def as_population_store(data) -> PopulationStore:
    """Normalize the engine's ``data`` argument: a store passes through, a
    ``to_device_arrays()``-style dict wraps in-memory, a path opens the
    on-disk format."""
    if isinstance(data, PopulationStore):
        return data
    if isinstance(data, dict):
        return InMemoryPopulationStore.from_arrays(data)
    if isinstance(data, (str, Path)):
        return MmapPopulationStore(data)
    raise TypeError(
        f"expected a PopulationStore, a to_device_arrays() dict, or a store "
        f"path, got {type(data).__name__}")
