"""Production-shape distributed steps: DP-FedAvg training round, prefill,
and decode — the units the multi-pod dry-run lowers and compiles.

``fed_train_step`` is Algorithm 1 at production shape: the global batch of
``train_4k`` is 256 *clients* (one local E=1 step each). Clients are laid
out one-per-(pod×data)-row; a ``lax.scan`` over client microbatches keeps
only ONE client's gradients live per device at a time; each client's update
is global-L2-clipped (f32 norm over the model-sharded pytree → psum) and
accumulated into an FSDP×TP-sharded f32 round sum; the round ends with the
1/qN average, f32 Gaussian noise (σ = zS/qN), and the Nesterov-momentum
server update. This mirrors how the production system's trusted aggregator
applies the mechanism, with the mesh playing the fleet (DESIGN.md §2).
"""
from __future__ import annotations

from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import DPConfig, InputShape, MeshConfig, ModelConfig
from repro.core.server_optim import ServerOptState
from repro.models.api import Model
from repro.sharding import specs as SP


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no device allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one step of the given input shape (dry-run stand-ins)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        out = {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
        if cfg.family == "encdec":
            out["frames"] = sds((b, cfg.n_audio_frames, cfg.d_model), bf16)
        if cfg.family == "vlm":
            out["image_embeds"] = sds((b, cfg.n_image_tokens, cfg.d_model), bf16)
        return out
    if shape.kind == "prefill":
        out = {"tokens": sds((b, s), i32)}
        if cfg.family == "encdec":
            out["frames"] = sds((b, cfg.n_audio_frames, cfg.d_model), bf16)
        if cfg.family == "vlm":
            out["image_embeds"] = sds((b, cfg.n_image_tokens, cfg.d_model), bf16)
        return out
    # decode: one new token against a seq_len cache
    return {"tokens": sds((b,), i32)}


def cache_shape(model: Model, shape: InputShape):
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))


def params_shape(model: Model):
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def opt_state_shape(params_sh):
    f32 = lambda t: jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32), t)
    return ServerOptState(momentum=f32(params_sh), nu=f32(params_sh),
                          count=jax.ShapeDtypeStruct((), jnp.int32))


# ---------------------------------------------------------------------------
# DP-FedAvg production train step
# ---------------------------------------------------------------------------


def make_fed_train_step(model: Model, dp: DPConfig, mesh, mesh_cfg: MeshConfig,
                        pspecs, shape: InputShape, *, client_lr: float = 0.5,
                        donate: bool = True, clients_per_row: int = 1):
    """Returns a jit'd (params, opt_state, batch, key) → (params, opt_state,
    metrics) with full in/out shardings attached.

    ``clients_per_row`` > 1 vmaps several clients per data-parallel row per
    microbatch — fewer microbatch iterations ⇒ fewer FSDP weight gathers
    (the dominant collective term), at the cost of holding that many
    per-client grad pytrees per device (§Perf iteration C4)."""
    rows = SP.batch_axis_size(mesh_cfg) * clients_per_row
    C = shape.global_batch
    assert C % rows == 0, (C, rows)
    n_micro = C // rows
    clip_S = dp.clip_norm
    mu = dp.server_momentum
    lr_s = dp.server_lr

    ns = lambda spec: NamedSharding(mesh, spec)
    pspecs_ns = jax.tree_util.tree_map(ns, pspecs)
    bspecs = SP.batch_specs(model.cfg, shape, mesh_cfg)

    def constrain(tree):
        return jax.tree_util.tree_map(
            lambda l, s: jax.lax.with_sharding_constraint(l, ns(s)),
            tree, pspecs)

    # HILLCLIMB(per-client-grad-shard): inside the client vmap the data axis
    # is taken by the client dimension, and GSPMD was dropping the MODEL
    # sharding of the per-client gradient pytrees — each device held a full
    # unsharded grad copy (phi3-medium train_4k: 33.7 GiB/chip temp). Pin
    # the tensor-parallel dims explicitly (FSDP dim → None under vmap).
    dp_axes = SP.batch_axes(mesh_cfg)

    def _client_grad_spec(spec):
        def one(e):
            if e == SP.FSDP:
                return None
            if isinstance(e, tuple):
                kept = tuple(a for a in e if a != SP.FSDP)
                return kept if kept else None
            return e
        # leading dim = the vmapped client axis, sharded over data(/pod)
        return P(dp_axes, *[one(e) for e in spec])

    grad_specs = jax.tree_util.tree_map(_client_grad_spec, pspecs)

    def step(params, opt_state, batch, key):
        cast = lambda l: l.astype(jnp.bfloat16) if l.dtype == jnp.float32 else l
        params_c = jax.tree_util.tree_map(cast, params)

        resh = lambda a: a.reshape((n_micro, rows, 1) + a.shape[1:])
        micro = jax.tree_util.tree_map(resh, batch)

        def per_client(cb):
            loss, g = jax.value_and_grad(model.loss_fn)(params_c, cb)
            ss = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                     for x in jax.tree_util.tree_leaves(g))
            norm = jnp.sqrt(ss) * client_lr          # ‖Δ‖ = η_c‖g‖ (E=1)
            factor = jnp.minimum(1.0, clip_S / jnp.maximum(norm, 1e-12))
            return g, norm, (factor < 1.0).astype(jnp.float32), loss, factor

        def micro_step(carry, mb):
            acc, msum, csum, lsum = carry
            gs, norms, clipped, losses, factors = jax.vmap(per_client)(mb)
            gs = jax.tree_util.tree_map(
                lambda x, s: jax.lax.with_sharding_constraint(x, ns(s)),
                gs, grad_specs)
            w = factors * (-client_lr)               # clip ∘ (Δ = −η_c g)
            # reduce straight into the FSDP×TP layout: the weighted client
            # sum is data-partial; pinning the einsum output to the param
            # spec makes GSPMD reduce-scatter instead of materializing an
            # f32 model-sharded-only partial (params/16 per microbatch).
            contrib = jax.tree_util.tree_map(
                lambda g, s: jax.lax.with_sharding_constraint(
                    jnp.einsum("c,c...->...", w, g,
                               preferred_element_type=jnp.float32), ns(s)),
                gs, pspecs)
            acc = constrain(jax.tree_util.tree_map(jnp.add, acc, contrib))
            return (acc, msum + jnp.sum(norms), csum + jnp.sum(clipped),
                    lsum + jnp.sum(losses)), None

        zeros = constrain(jax.tree_util.tree_map(
            lambda l: jnp.zeros(l.shape, jnp.float32), params))
        (acc, msum, csum, lsum), _ = jax.lax.scan(
            micro_step, (zeros, jnp.zeros(()), jnp.zeros(()), jnp.zeros(())),
            micro)

        # Algorithm 1 server side: average, f32 noise, Nesterov momentum.
        sigma = dp.noise_multiplier * clip_S / C
        leaves, treedef = jax.tree_util.tree_flatten(acc)
        keys = jax.random.split(key, len(leaves))
        noised = [l / C + sigma * jax.random.normal(k, l.shape, jnp.float32)
                  for l, k in zip(leaves, keys)]
        delta = jax.tree_util.tree_unflatten(treedef, noised)
        new_m = jax.tree_util.tree_map(
            lambda m, d: mu * m + d, opt_state.momentum, delta)
        step_tree = jax.tree_util.tree_map(
            lambda m, d: mu * m + d, new_m, delta)       # Nesterov
        new_params = jax.tree_util.tree_map(
            lambda p, s: (p.astype(jnp.float32) + lr_s * s).astype(p.dtype),
            params, step_tree)
        new_state = opt_state._replace(momentum=new_m,
                                       count=opt_state.count + 1)
        metrics = {"loss": lsum / C, "mean_update_norm": msum / C,
                   "frac_clipped": csum / C, "noise_std": sigma}
        return new_params, new_state, metrics

    opt_specs = ServerOptState(momentum=pspecs, nu=pspecs, count=P())
    in_sh = (pspecs_ns, jax.tree_util.tree_map(ns, opt_specs),
             jax.tree_util.tree_map(ns, bspecs), ns(P()))
    out_sh = (pspecs_ns, jax.tree_util.tree_map(ns, opt_specs),
              ns(P()))
    return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(0, 1) if donate else ())


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------


def make_prefill_step(model: Model, mesh, mesh_cfg: MeshConfig, pspecs,
                      shape: InputShape):
    ns = lambda spec: NamedSharding(mesh, spec)
    bspecs = SP.batch_specs(model.cfg, shape, mesh_cfg)
    bspecs.pop("labels", None)
    c_sh = SP.cache_specs(cache_shape(model, shape), model.cfg, shape, mesh_cfg)
    dp = SP.batch_axes(mesh_cfg)
    b_ok = shape.global_batch % SP.batch_axis_size(mesh_cfg) == 0
    logits_spec = P(dp if b_ok else None, "model")
    in_sh = (jax.tree_util.tree_map(ns, pspecs),
             jax.tree_util.tree_map(ns, bspecs))
    out_sh = (ns(logits_spec), jax.tree_util.tree_map(ns, c_sh))
    return jax.jit(lambda p, b: model.prefill(p, b),
                   in_shardings=in_sh, out_shardings=out_sh)


def make_decode_step(model: Model, mesh, mesh_cfg: MeshConfig, pspecs,
                     shape: InputShape, *, donate: bool = True):
    ns = lambda spec: NamedSharding(mesh, spec)
    c_sh = SP.cache_specs(cache_shape(model, shape), model.cfg, shape, mesh_cfg)
    c_ns = jax.tree_util.tree_map(ns, c_sh)
    dp = SP.batch_axes(mesh_cfg)
    b_ok = shape.global_batch % SP.batch_axis_size(mesh_cfg) == 0
    tok_spec = P(dp) if b_ok else P(None)
    logits_spec = P(dp if b_ok else None, "model")
    in_sh = (jax.tree_util.tree_map(ns, pspecs), ns(tok_spec), c_ns)
    out_sh = (ns(logits_spec), c_ns)
    return jax.jit(lambda p, t, c: model.decode_step(p, t, c),
                   in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(2,) if donate else ())
