"""Serving CLI: a thin frontend over the continuous-batching engine
(`repro.serve.ServeEngine`) — session admission, batched decode with the
device-resident state cache, top-k suggestion candidates, optional
checkpoint hot-swap drill.

    PYTHONPATH=src python -m repro.launch.serve --arch gboard-cifg-lstm \
        --ckpt experiments/runs/gboard-cifg-lstm_r100.msgpack --steps 8

``--reference`` runs the fixed one-shot batch path (:func:`generate`)
instead of the engine; it is kept as the pre-engine batch reference and the
regression surface for the historical decode bugs (``steps=0`` emitting a
token, ``temperature>0`` with no key crashing, batch rows sharing one
sampling stream).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.tokenizer import BOS
from repro.models import build
from repro.serve import NwpRequest, ServeEngine
from repro.serve.sampling import sample_tokens
from repro.train import checkpoint


def generate(model, params, prompts: jnp.ndarray, steps: int,
             temperature: float = 0.0, key=None, max_len: int = None):
    """prompts: (B, S0) int32 → (B, S0+steps). Greedy if temperature=0.

    ``steps=0`` returns exactly the prompts. Temperature sampling requires
    ``key``; each batch row samples from its own stream
    (``fold_in(key, row)`` is the row's session key — see
    `repro.serve.sampling` for the schedule the serving engine shares).
    """
    if steps < 0:
        raise ValueError(f"steps must be >= 0, got {steps}")
    if temperature > 0.0 and key is None:
        raise ValueError(
            "generate(temperature>0) needs a PRNG key: pass "
            "key=jax.random.PRNGKey(seed) so sampling is reproducible "
            "(greedy decoding, temperature=0, needs none)")
    B, S0 = prompts.shape
    max_len = max_len or (S0 + steps)
    last, cache = model.prefill(params, {"tokens": prompts}, max_len=max_len)
    if steps == 0:
        return prompts
    vocab = model.cfg.vocab
    if key is None:
        row_keys = jnp.zeros((B, 2), jnp.uint32)  # greedy: keys unused
    else:
        row_keys = jax.vmap(jax.random.fold_in, (None, 0))(
            key, jnp.arange(B))
    temps = jnp.full((B,), temperature, jnp.float32)
    decode_j = jax.jit(model.decode_step)
    sample_j = jax.jit(sample_tokens)

    def pick(logits, t):
        return sample_j(logits[:, :vocab], row_keys,
                        jnp.full((B,), t, jnp.int32), temps)

    toks = [pick(last, 0)]
    for t in range(1, steps):
        logits, cache = decode_j(params, toks[-1], cache)
        toks.append(pick(logits, t))
    return jnp.concatenate([prompts, jnp.stack(toks, axis=1)], axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gboard-cifg-lstm")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--batch", type=int, default=4,
                    help="number of sessions to submit")
    ap.add_argument("--slots", type=int, default=None,
                    help="engine decode slots (default: --batch)")
    ap.add_argument("--prompt-len", type=int, default=4)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="base sampling seed (session i uses seed+i)")
    ap.add_argument("--top-k", type=int, default=3,
                    help="suggestion-strip candidates per position")
    ap.add_argument("--vocab", type=int, default=2000)
    ap.add_argument("--hot-swap", default=None, metavar="CKPT",
                    help="promote this checkpoint mid-run (hot-swap demo)")
    ap.add_argument("--reference", action="store_true",
                    help="run the one-shot batch reference path instead "
                         "of the continuous-batching engine")
    ap.add_argument("--cell-path", default=None,
                    choices=["auto", "fused", "seq", "ref"],
                    help="lstm recurrence implementation (decode_step runs "
                         "the same fused Pallas cell as training)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family == "lstm":
        cfg = cfg.with_(vocab=args.vocab)
    if args.cell_path is not None:
        cfg = cfg.with_(cell_path=args.cell_path)
    model = build(cfg)
    if args.ckpt:
        params, meta = checkpoint.load(args.ckpt)
        params = jax.tree_util.tree_map(jnp.asarray, params)
        print(f"loaded checkpoint ({meta})")
    else:
        params = model.init(jax.random.PRNGKey(0))
        print("serving a randomly initialized model (pass --ckpt)")

    key = jax.random.PRNGKey(args.seed + 1)
    prompts = np.full((args.batch, args.prompt_len), BOS, np.int32)
    prompts[:, 1:] = np.asarray(
        jax.random.randint(key, (args.batch, args.prompt_len - 1), 4,
                           cfg.vocab))

    if args.reference:
        out = generate(model, params, jnp.asarray(prompts), args.steps,
                       args.temperature,
                       jax.random.PRNGKey(args.seed)
                       if args.temperature > 0 else None)
        for row in np.asarray(out):
            print("prompt:", row[:args.prompt_len].tolist(),
                  "→ continuation:", row[args.prompt_len:].tolist())
        return

    engine = ServeEngine(model, params, max_slots=args.slots or args.batch,
                         top_k=args.top_k)
    sids = [engine.submit(NwpRequest(
        prompt=tuple(int(t) for t in prompts[i]), steps=args.steps,
        temperature=args.temperature,
        seed=args.seed + i if args.temperature > 0 else None))
        for i in range(args.batch)]
    if args.hot_swap:
        for _ in range(max(1, args.steps // 2)):
            engine.step()
        v = engine.load_checkpoint(args.hot_swap)
        print(f"hot-swapped to {args.hot_swap} (params v{v}, "
              f"{engine.active_sessions} sessions in flight)")
    engine.run()
    for sid in sids:
        r = engine.result(sid)
        print(f"{sid} [{r.status}] prompt: {list(r.prompt)} → "
              f"continuation: {list(r.tokens)} "
              f"(strip: {r.candidates[-1].tolist() if len(r.tokens) else []})")


if __name__ == "__main__":
    main()
