"""Batched serving driver: prefill a batch of prompts, then decode with the
KV/state cache (greedy or temperature sampling).

    PYTHONPATH=src python -m repro.launch.serve --arch gboard-cifg-lstm \
        --ckpt experiments/runs/gboard-cifg-lstm_r100.msgpack --steps 8
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.tokenizer import BOS
from repro.models import build
from repro.train import checkpoint


def generate(model, params, prompts: jnp.ndarray, steps: int,
             temperature: float = 0.0, key=None, max_len: int = None):
    """prompts: (B, S0) int32 → (B, S0+steps). Greedy if temperature=0."""
    B, S0 = prompts.shape
    max_len = max_len or (S0 + steps)
    last, cache = model.prefill(params, {"tokens": prompts}, max_len=max_len)
    prefill_j = None
    decode_j = jax.jit(model.decode_step)
    toks = []
    vocab = model.cfg.vocab
    cur = _pick(last[:, :vocab], temperature, key, 0)
    toks.append(cur)
    for t in range(1, steps):
        logits, cache = decode_j(params, cur, cache)
        cur = _pick(logits[:, :vocab], temperature, key, t)
        toks.append(cur)
    return jnp.concatenate([prompts, jnp.stack(toks, axis=1)], axis=1)


def _pick(logits, temperature, key, t):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    k = jax.random.fold_in(key, t)
    return jax.random.categorical(k, logits / temperature).astype(jnp.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gboard-cifg-lstm")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=4)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--vocab", type=int, default=2000)
    ap.add_argument("--cell-path", default=None,
                    choices=["auto", "fused", "seq", "ref"],
                    help="lstm recurrence implementation (decode_step runs "
                         "the same fused Pallas cell as training)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family == "lstm":
        cfg = cfg.with_(vocab=args.vocab)
    if args.cell_path is not None:
        cfg = cfg.with_(cell_path=args.cell_path)
    model = build(cfg)
    if args.ckpt:
        params, meta = checkpoint.load(args.ckpt)
        params = jax.tree_util.tree_map(jnp.asarray, params)
        print(f"loaded checkpoint ({meta})")
    else:
        params = model.init(jax.random.PRNGKey(0))
        print("serving a randomly initialized model (pass --ckpt)")

    key = jax.random.PRNGKey(1)
    prompts = np.full((args.batch, args.prompt_len), BOS, np.int32)
    prompts[:, 1:] = np.asarray(
        jax.random.randint(key, (args.batch, args.prompt_len - 1), 4,
                           cfg.vocab))
    out = generate(model, params, jnp.asarray(prompts), args.steps,
                   args.temperature, key)
    for row in np.asarray(out):
        print("prompt:", row[:args.prompt_len].tolist(),
              "→ continuation:", row[args.prompt_len:].tolist())


if __name__ == "__main__":
    main()
