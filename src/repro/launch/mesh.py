"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips of a
v5e pod. Multi-pod: (pod=2, data=16, model=16) = 512 chips; clients shard
across pods, params replicate across pods (hybrid FSDP), so only the
DP-FedAvg round-sum block partials cross the inter-pod links — the engine's
canonical cross-pod reduction (`repro.fl.reduction.fold_pods`) folds each
pod's blocks pod-locally and sends only the pod partials over the ``pod``
axis.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

import jax

from repro.configs.base import MULTI_POD, SINGLE_POD, MeshConfig

# Axis layouts make_cohort_mesh accepts: the cohort's batch axes only (the
# 1-D sim layout, or the multi-pod batch slice of the production mesh).
COHORT_AXES = (("data",), ("pod", "data"))


def make_production_mesh(*, multi_pod: bool = False,
                         shape: Optional[Tuple[int, ...]] = None):
    """Concrete production mesh: ``(data, model)`` or, with ``multi_pod``,
    ``(pod, data, model)``. ``shape`` overrides the chip counts (same axis
    order) for test-scale meshes on forced host devices; it must keep one
    entry per axis."""
    cfg = mesh_config(multi_pod=multi_pod)
    shape = cfg.shape if shape is None else tuple(shape)
    if len(shape) != len(cfg.axes):
        raise ValueError(
            f"make_production_mesh: shape {shape} must have one entry per "
            f"axis {cfg.axes}")
    return jax.make_mesh(shape, cfg.axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD if multi_pod else SINGLE_POD


def make_cohort_mesh(mesh_cfg: MeshConfig):
    """Concrete device mesh for the simulation engine's sharded cohort: the
    1-D ``(data,)`` sim layout or the 2-D ``(pod, data)`` batch slice of the
    multi-pod production mesh.

    Takes the first ``n_devices`` local devices (CPU included — CI forces
    host devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)
    and lays them out over the config's batch axes. The engine owns the
    cross-pod round reduction on this mesh (pod-local canonical block folds;
    only the pod partials cross the ``pod`` axis — see `repro.fl.engine`);
    model-parallel axes stay the launch layer's job, so a config carrying a
    ``model`` axis is rejected here.
    """
    if tuple(mesh_cfg.axes) not in COHORT_AXES:
        raise ValueError(
            "make_cohort_mesh expects a cohort MeshConfig over the batch "
            f"axes only — ('data',) or ('pod', 'data') — got {mesh_cfg}. "
            "Model-parallel axes are the launch layer's job; build the "
            "cohort slice with sharding.specs.sim_mesh_config(num_shards, "
            "num_pods).")
    n = mesh_cfg.n_devices
    devices = jax.devices()
    if len(devices) < n:
        raise ValueError(
            f"cohort mesh needs {n} devices but only {len(devices)} are "
            "visible. On CPU, force host devices with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} (set it before "
            "importing jax).")
    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(mesh_cfg.shape), mesh_cfg.axes)
