"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips of a
v5e pod. Multi-pod: (pod=2, data=16, model=16) = 512 chips; clients shard
across pods, params replicate across pods (hybrid FSDP), so only the
DP-FedAvg round reduction crosses the inter-pod links.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.configs.base import MULTI_POD, SINGLE_POD, MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD if multi_pod else SINGLE_POD


def make_cohort_mesh(mesh_cfg: MeshConfig):
    """Concrete 1-D device mesh for the simulation engine's sharded cohort.

    Takes the first ``n_devices`` local devices (CPU included — CI forces
    8 host devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)
    and lays them out over the mesh's single batch axis. The engine keeps its
    mesh 1-D; the cross-pod reduction of the multi-pod production mesh is the
    launch layer's job (see ROADMAP).
    """
    if len(mesh_cfg.shape) != 1:
        raise ValueError(
            "make_cohort_mesh expects a 1-D MeshConfig (the sim engine "
            f"shards the cohort over a single axis); got {mesh_cfg}. Use "
            "sharding.specs.sim_mesh_config(num_shards).")
    n = mesh_cfg.n_devices
    devices = jax.devices()
    if len(devices) < n:
        raise ValueError(
            f"cohort mesh needs {n} devices but only {len(devices)} are "
            "visible. On CPU, force host devices with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} (set it before "
            "importing jax).")
    return jax.sharding.Mesh(np.asarray(devices[:n]), mesh_cfg.axes)
