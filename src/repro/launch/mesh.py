"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips of a
v5e pod. Multi-pod: (pod=2, data=16, model=16) = 512 chips; clients shard
across pods, params replicate across pods (hybrid FSDP), so only the
DP-FedAvg round reduction crosses the inter-pod links.
"""
from __future__ import annotations

import jax

from repro.configs.base import MULTI_POD, SINGLE_POD, MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD if multi_pod else SINGLE_POD
