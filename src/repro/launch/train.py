"""Federated training driver (end-to-end, CPU-scale simulation).

    PYTHONPATH=src python -m repro.launch.train --arch gboard-cifg-lstm \
        --rounds 100 --clients-per-round 40 --noise-multiplier 0.3

Runs Algorithm 1 on a simulated device population (availability gating +
Pace Steering), tracks the RDP accountant, optionally injects secret-sharing
canary devices, and checkpoints the server model.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import numpy as np

from repro.configs import ClientConfig, DPConfig, get_config
from repro.core.secret_sharer import make_canaries
from repro.data.corpus import BigramCorpus
from repro.data.federated import FederatedDataset
from repro.data.population_store import MmapPopulationStore
from repro.fl.faults import FaultConfig
from repro.fl.round import FederatedTrainer
from repro.models import build
from repro.train import checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gboard-cifg-lstm")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale variant of the arch")
    ap.add_argument("--vocab", type=int, default=2000)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--n-users", type=int, default=300)
    ap.add_argument("--clients-per-round", type=int, default=40)
    ap.add_argument("--noise-multiplier", type=float, default=0.3)
    ap.add_argument("--clip-norm", type=float, default=0.8)
    ap.add_argument("--server-opt", default="momentum",
                    choices=["sgd", "momentum", "adam"])
    ap.add_argument("--server-lr", type=float, default=0.5)
    ap.add_argument("--server-momentum", type=float, default=0.9)
    ap.add_argument("--client-lr", type=float, default=0.3)
    ap.add_argument("--client-batch", type=int, default=10)
    ap.add_argument("--local-epochs", type=int, default=1)
    ap.add_argument("--inject-canaries", action="store_true")
    ap.add_argument("--seq-len", type=int, default=16)
    ap.add_argument("--out", default="experiments/runs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="engine",
                    choices=["engine", "engine_python", "host"],
                    help="engine = compiled multi-round simulator "
                         "(repro.fl.engine); host = numpy reference loop")
    ap.add_argument("--rounds-per-call", type=int, default=10,
                    help="rounds fused per jit call (engine backend)")
    ap.add_argument("--num-shards", type=int, default=1,
                    help="shard the per-round cohort axis across this many "
                         "devices per pod (engine backend; on CPU force "
                         "devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--num-pods", type=int, default=1,
                    help="lay the cohort shards out over this many pods — "
                         "the 2-D (pod, data) batch slice of the production "
                         "mesh; needs num_pods x num_shards visible devices "
                         "(engine backend)")
    ap.add_argument("--cohort-chunk", type=int, default=None,
                    help="stream the round sum this many clients at a time "
                         "(peak update memory is O(chunk); default: auto — "
                         "largest divisor of the canonical block size ≤ 32; "
                         "0 = legacy materializing path)")
    ap.add_argument("--clip-path", default="fused",
                    choices=["fused", "tree"],
                    help="per-client clip→accumulate implementation: fused "
                         "Pallas dp_clip kernels (interpret mode on CPU, "
                         "compiled on TPU) or the pytree reference")
    ap.add_argument("--cell-path", default=None,
                    choices=["auto", "fused", "seq", "ref"],
                    help="lstm recurrence implementation: time-fused "
                         "sequence op with the Pallas cifg_cell kernel "
                         "(fused) or the jnp cell (seq), plain autodiff "
                         "scan (ref), or auto = fused on TPU / seq "
                         "elsewhere (default: the config's cell_path)")
    ap.add_argument("--population-backend", default=None,
                    choices=["device", "streamed"],
                    help="device = whole padded corpus resident on device "
                         "(simulation default); streamed = corpus stays on "
                         "the host and one cohort is staged per round with "
                         "double-buffered prefetch (engine backend; "
                         "bit-exact vs device)")
    ap.add_argument("--population-store", default=None, metavar="DIR",
                    help="path to an on-disk population store directory "
                         "(see tools/build_corpus.py); replaces the "
                         "synthesized FederatedDataset and implies "
                         "--population-backend streamed unless overridden")
    ap.add_argument("--sampler", default="global",
                    choices=["global", "sharded"],
                    help="cohort-selection implementation (engine backend): "
                         "global = monolithic O(N) sampler on one device "
                         "(the historical trajectory family); sharded = "
                         "mesh-sharded block-local Gumbel top-k "
                         "(fl.pop_sampler) — O(N) population state and "
                         "selection work shard over (pod, data), use at "
                         "fleet scale")
    ap.add_argument("--availability", type=float, default=0.3,
                    help="per-round device check-in probability; keep "
                         "availability·n_users above clients_per_round")
    ap.add_argument("--fault-dropout", type=float, default=0.0,
                    help="per-selected-client dropout probability (accepts "
                         "the task, never reports); any fault flag > 0 "
                         "enables the over-selection/report-goal round "
                         "protocol (engine backend)")
    ap.add_argument("--fault-straggler", type=float, default=0.0,
                    help="fraction of selected clients whose report latency "
                         "is Exponential(--fault-straggler-delay)")
    ap.add_argument("--fault-straggler-delay", type=float, default=1.0,
                    help="mean straggler report latency (same units as "
                         "--fault-deadline)")
    ap.add_argument("--fault-deadline", type=float, default=3.0,
                    help="round deadline; straggler reports past it are "
                         "dropped from the round")
    ap.add_argument("--fault-corrupt", type=float, default=0.0,
                    help="probability a delivered report is non-finite "
                         "garbage (rejected by the server-side guard)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed of the fault stream (disjoint from --seed's "
                         "training PRNG chain)")
    ap.add_argument("--report-goal", type=int, default=None,
                    help="minimum usable reports for a round to commit; "
                         "rounds below it abort (no server step, no privacy "
                         "spend). Default: ceil(0.8 x clients_per_round) "
                         "when faults are on")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="persist durable run state every N rounds (engine "
                         "backend); 0 = only the final checkpoint")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the run-state snapshot in --out if "
                         "one exists; the finished run is bit-identical to "
                         "an uninterrupted one")
    ap.add_argument("--crash-after", type=int, default=None,
                    help="simulate a crash: exit (skipping the final "
                         "checkpoint) once this many rounds are done — for "
                         "exercising --resume")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family == "lstm":
        cfg = cfg.with_(vocab=args.vocab)
    if args.cell_path is not None:
        cfg = cfg.with_(cell_path=args.cell_path)
    model = build(cfg)

    store = None
    if args.population_store is not None:
        if args.inject_canaries:
            raise SystemExit("--inject-canaries builds synthetic devices "
                             "into a FederatedDataset; bake them into the "
                             "store instead (tools/build_corpus.py "
                             "--inject-canaries)")
        store = MmapPopulationStore(args.population_store)
        ds = None
        n_users = store.n_users
        synth_ids = np.nonzero(np.asarray(store.synthetic))[0].tolist()
        print(f"population store: {args.population_store} "
              f"({n_users} users, E_max={store.emax}, "
              f"seq_len={store.row_len - 1}, {len(synth_ids)} synthetic)")
    else:
        corpus = BigramCorpus(vocab_size=cfg.vocab, seed=args.seed)
        ds = FederatedDataset(corpus, n_users=args.n_users,
                              seq_len=args.seq_len, sentences_per_user=30)
        canaries = []
        if args.inject_canaries:
            canaries = make_canaries(jax.random.PRNGKey(42), vocab=cfg.vocab)
            ds.inject_canaries(canaries)
            print(f"injected {len(canaries)} canaries "
                  f"({sum(c.n_u for c in canaries)} synthetic devices)")
        n_users = len(ds.users)
        synth_ids = [u.user_id for u in ds.users if u.is_synthetic]

    dp = DPConfig(clients_per_round=args.clients_per_round,
                  noise_multiplier=args.noise_multiplier,
                  clip_norm=args.clip_norm, server_opt=args.server_opt,
                  server_lr=args.server_lr,
                  server_momentum=args.server_momentum)
    cl = ClientConfig(local_epochs=args.local_epochs,
                      batch_size=args.client_batch, lr=args.client_lr)
    population_backend = args.population_backend or (
        "streamed" if store is not None else "device")
    if population_backend == "streamed" and args.backend == "host":
        raise SystemExit("--population-backend streamed needs the engine "
                         "backend (the host loop reads the dataset directly)")
    if args.sampler != "global" and args.backend == "host":
        raise SystemExit("--sampler sharded needs the engine backend (the "
                         "host loop samples via PopulationSim)")
    faults = None
    if (args.fault_dropout > 0 or args.fault_straggler > 0
            or args.fault_corrupt > 0 or args.report_goal is not None):
        faults = FaultConfig(seed=args.fault_seed,
                             dropout_prob=args.fault_dropout,
                             straggler_prob=args.fault_straggler,
                             straggler_mean_delay=args.fault_straggler_delay,
                             round_deadline=args.fault_deadline,
                             corrupt_prob=args.fault_corrupt,
                             report_goal=args.report_goal)
    if args.backend == "host" and (faults is not None or args.resume
                                   or args.checkpoint_every > 0
                                   or args.crash_after is not None):
        raise SystemExit("--fault-*/--report-goal/--checkpoint-every/"
                         "--resume/--crash-after need the engine backend "
                         "(the fault protocol and durable run state live in "
                         "the engine round bodies)")
    from repro.fl.population import PopulationSim
    pop = PopulationSim(n_users, availability=args.availability,
                        synthetic_ids=synth_ids, seed=args.seed)
    trainer = FederatedTrainer(model, ds, dp, cl, pop=pop, seed=args.seed,
                               n_local_batches=3, backend=args.backend,
                               rounds_per_call=args.rounds_per_call,
                               num_shards=args.num_shards,
                               num_pods=args.num_pods,
                               cohort_chunk=args.cohort_chunk,
                               clip_path=args.clip_path,
                               population_backend=population_backend,
                               population_store=store,
                               sampler=args.sampler,
                               fault_config=faults)

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    log_every = max(1, args.rounds // 20)
    state_path = out / f"{args.arch}_r{args.rounds}_state.msgpack"
    done = 0
    if args.resume and state_path.exists():
        done = trainer.restore_run_state(state_path)
        print(f"resumed from {state_path} at round {done}")
    chunk = args.checkpoint_every if args.checkpoint_every > 0 \
        else args.rounds
    while done < args.rounds:
        k = min(chunk - done % chunk, args.rounds - done)
        if args.crash_after is not None:
            k = min(k, args.crash_after - done)
        trainer.train(k, log_every=log_every)
        done += k
        if args.checkpoint_every > 0 and done % args.checkpoint_every == 0 \
                and done < args.rounds:
            trainer.save_run_state(state_path)
        if args.crash_after is not None and done >= args.crash_after:
            print(f"simulated crash after round {done} "
                  f"(resume with --resume)")
            return

    committed = sum(r.get("committed", True)
                    for r in trainer.state.history)
    eps = trainer.accountant.get_epsilon(1e-6)
    print(f"RDP accountant after {args.rounds} rounds "
          f"({committed} committed): eps={eps:.2f} at delta=1e-6 "
          f"(q={trainer.accountant.q:.4f})")

    ck = out / f"{args.arch}_r{args.rounds}.msgpack"
    checkpoint.save(ck, trainer.state.params,
                    meta={"arch": args.arch, "rounds": str(args.rounds),
                          "eps@1e-6": f"{eps:.3f}"})
    (out / f"{args.arch}_r{args.rounds}_history.json").write_text(
        json.dumps(trainer.state.history[-50:], indent=1))
    print(f"checkpoint: {ck}")


if __name__ == "__main__":
    main()
