"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

MUST be imported/run before any other jax usage: the first two lines pin the
placeholder device count for the production meshes (dry-run ONLY — smoke
tests and benches see the real single CPU device).
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import (ALL_ARCHS, ASSIGNED_ARCHS, INPUT_SHAPES, DPConfig,
                           InputShape, ModelConfig, get_config)
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh, mesh_config
from repro.models import build
from repro.sharding import specs as SP
from repro.utils.compat import set_mesh

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

FULL_ATTN_FAMILIES = ("dense", "moe", "vlm", "encdec")
LONG_WINDOW = 4096

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def arch_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """long_500k requires sub-quadratic attention: full-attention families
    switch to the sliding-window variant (window 4096). SSM runs natively;
    the hybrid's shared-attention KV stays exact (DESIGN.md §4)."""
    if shape.name == "long_500k" and cfg.family in FULL_ATTN_FAMILIES:
        return cfg.with_(attn_window=LONG_WINDOW)
    return cfg


def _shape_bytes(stype: str) -> int:
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", stype)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_stats(hlo_text: str):
    """Sum result bytes of every collective op in the optimized HLO."""
    stats = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    pat = re.compile(
        r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
        r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start)?\(")
    for m in pat.finditer(hlo_text):
        stype, op = m.groups()
        total = sum(_shape_bytes(s)
                    for s in re.findall(r"[a-z0-9]+\[[0-9,]*\]", stype))
        stats[op]["count"] += 1
        stats[op]["bytes"] += total
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items()
                               if isinstance(v, dict))
    return stats


def count_params(params_sh) -> int:
    return sum(int(l.size if hasattr(l, "size") else 0)
               for l in jax.tree_util.tree_leaves(params_sh))


def dryrun_one(arch: str, shape_name: str, multi_pod: bool,
               save: bool = True, verbose: bool = True):
    shape = INPUT_SHAPES[shape_name]
    cfg = arch_for_shape(get_config(arch), shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mcfg = mesh_config(multi_pod=multi_pod)
    model = build(cfg)
    t0 = time.time()

    params_sh = ST.params_shape(model)
    pspecs = SP.param_specs(params_sh, cfg, mcfg)
    inputs = ST.input_specs(cfg, shape)

    with set_mesh(mesh):
        if shape.kind == "train":
            opt_sh = ST.opt_state_shape(params_sh)
            fn = ST.make_fed_train_step(model, DPConfig(
                clients_per_round=shape.global_batch), mesh, mcfg, pspecs,
                shape, donate=True)
            key_sh = jax.ShapeDtypeStruct((2,), jnp.uint32)
            lowered = fn.lower(params_sh, opt_sh, inputs, key_sh)
        elif shape.kind == "prefill":
            fn = ST.make_prefill_step(model, mesh, mcfg, pspecs, shape)
            lowered = fn.lower(params_sh, inputs)
        else:  # decode
            fn = ST.make_decode_step(model, mesh, mcfg, pspecs, shape,
                                     donate=True)
            cache_sh = ST.cache_shape(model, shape)
            lowered = fn.lower(params_sh, inputs["tokens"], cache_sh)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "n_devices": mesh.devices.size,
           "n_params": count_params(params_sh),
           "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1)}

    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)}
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}

    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and
                       k in ("flops", "bytes accessed", "transcendentals",
                             "bytes accessed output", "optimal_seconds")}
    except Exception as e:  # pragma: no cover
        rec["cost"] = {"error": str(e)}

    try:
        rec["collectives"] = collective_stats(compiled.as_text())
    except Exception as e:  # pragma: no cover
        rec["collectives"] = {"error": str(e)}

    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        out = RESULTS_DIR / f"{arch}__{shape_name}__{rec['mesh']}.json"
        out.write_text(json.dumps(rec, indent=1))
    if verbose:
        flops = rec.get("cost", {}).get("flops", 0)
        cb = rec.get("collectives", {}).get("total_bytes", 0)
        print(f"[dryrun] {arch:22s} {shape_name:12s} {rec['mesh']:8s} "
              f"compile={rec['compile_s']:6.1f}s flops={flops:.3e} "
              f"coll={cb/1e9:.2f}GB", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--include-paper-model", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    if args.include_paper_model and "gboard-cifg-lstm" not in archs:
        archs.append("gboard-cifg-lstm")
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    dryrun_one(arch, shape, mp)
                except Exception as e:
                    failures.append((arch, shape, mp, repr(e)))
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f[:3], f[3][:200])
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
