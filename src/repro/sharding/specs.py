"""PartitionSpec trees for params, inputs, and caches, per architecture.

Scheme (DESIGN.md §2/§4): MaxText-style 2-D sharding —
  * ``model`` axis: tensor-parallel (Megatron) sharding of d_ff / attention
    heads / vocab / experts / d_inner;
  * ``data`` axis: FSDP sharding of the *other* param dim + one client (or
    batch element) per data row;
  * ``pod`` axis (multi-pod): clients/batch sharded across pods; params are
    replicated across pods (hybrid-FSDP) so per-layer all-gathers stay on
    intra-pod ICI and only the DP round-sum crosses pods.

Where a dimension does not divide the 16-way model axis (kv_heads ∈ {8,10,12},
granite-moe's 40 experts, odd vocabs) we fall back per-rule: KV caches shard
their *sequence* dim (flash-decode style distributed softmax), MoE shards
expert d_ff instead of the expert dim, vocab is padded to 256 (embed.py).
Attention projections always shard on the flat H·hd/KV·hd output dim (a
multiple of 16 for every assigned arch) — §Perf iteration C0.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, MeshConfig, ModelConfig

STACKED_ROOTS = ("layers", "mamba_layers", "enc_layers", "dec_layers")


def _axis_sizes(mesh_cfg: MeshConfig) -> Dict[str, int]:
    return dict(zip(mesh_cfg.axes, mesh_cfg.shape))


def batch_axes(mesh_cfg: MeshConfig):
    """Axes the client/batch dimension shards over."""
    return ("pod", "data") if "pod" in mesh_cfg.axes else ("data",)


def batch_axis_size(mesh_cfg: MeshConfig) -> int:
    sizes = _axis_sizes(mesh_cfg)
    n = 1
    for a in batch_axes(mesh_cfg):
        n *= sizes[a]
    return n


def sim_mesh_config(num_shards: int, num_pods: int = 1) -> MeshConfig:
    """Cohort mesh for the simulation engine's sharded cohort
    (`repro.fl.engine.SimEngine(num_shards=..., num_pods=...)`): the 1-D
    ``(data,)`` layout, or — with ``num_pods > 1`` — the 2-D
    ``(pod, data)`` batch slice of the multi-pod production mesh. The
    cohort shards over exactly the axes :func:`batch_axes` names — the
    same layout the production `launch.steps.fed_train_step` uses for its
    client dimension — so a sim-validated (pods, shards) point carries
    over to the real mesh."""
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if num_pods < 1:
        raise ValueError(f"num_pods must be >= 1, got {num_pods}")
    if num_pods == 1:
        return MeshConfig((num_shards,), ("data",))
    return MeshConfig((num_pods, num_shards), ("pod", "data"))


def cohort_spec(mesh_cfg: MeshConfig):
    """PartitionSpec of the per-round cohort/client axis: sharded over
    :func:`batch_axes` (``data``, plus ``pod`` on multi-pod meshes)."""
    axes = batch_axes(mesh_cfg)
    return P(axes[0] if len(axes) == 1 else axes)


def population_spec(mesh_cfg: MeshConfig):
    """PartitionSpec of the padded population/user axis under the sharded
    cohort sampler (`fl.pop_sampler`): identical layout rule to
    :func:`cohort_spec` — both axes shard pod-major over the mesh's batch
    axes, so a shard's population rows and its cohort slots live on the
    same devices (candidate merge and cohort staging never cross an extra
    boundary)."""
    return cohort_spec(mesh_cfg)


FSDP = "data"     # params FSDP-shard over data (replicated across pods)
MP = "model"


def _path_names(path):
    names = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            names.append(str(k.key))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            names.append(k.name)
        else:
            names.append(str(k))
    return names


def _leaf_spec(names, leaf, cfg: ModelConfig, mp: int):
    """PartitionSpec for one param leaf (without the stacked-layer dim)."""
    name = names[-1]
    heads_ok = cfg.n_heads % mp == 0
    ssm_heads_ok = cfg.ssm_heads % mp == 0 if cfg.ssm_heads else False
    experts_ok = cfg.n_experts % mp == 0 if cfg.n_experts else False
    nd = leaf.ndim - (1 if names[0] in STACKED_ROOTS else 0)

    if name == "tok":
        # tied: vocab (model) × d (fsdp) serves both lookup and head
        return P(MP, FSDP) if cfg.tie_embeddings else P(FSDP, MP)
    if name == "head":
        return P(MP, FSDP)
    if name in ("wq", "wk", "wv"):
        # H·hd and KV·hd are multiples of 16 for every assigned arch, so the
        # flat projection output always shards even when H % 16 ≠ 0 (the
        # reshape to heads may reshard activations — small per client).
        return P(FSDP, MP)
    if name == "wo":
        return P(MP, FSDP)
    if name in ("w_gate", "w_up"):
        if nd == 3:  # MoE expert-stacked
            return (P(MP, FSDP, None) if experts_ok else P(None, FSDP, MP))
        return P(FSDP, MP)
    if name == "w_down":
        if nd == 3:
            return (P(MP, None, FSDP) if experts_ok else P(None, MP, FSDP))
        return P(MP, FSDP)
    if name == "w_in":
        return P(FSDP, MP)
    if name == "w_out":  # gelu-MLP down proj AND mamba out proj
        return P(MP, FSDP)
    if name == "b_in":
        return P(MP)
    if name == "b_out":
        return P(None)
    if name in ("w_z", "w_x"):  # mamba in-proj AND CIFG-LSTM input gates
        return P(FSDP, MP)
    if name in ("w_B", "w_C", "w_dt"):
        return P(FSDP, None)
    if name == "conv_x":
        return P(None, MP)
    if name in ("conv_B", "conv_C"):
        return P(None, None)
    if name == "conv_b_x":
        return P(MP)
    if name in ("conv_b_B", "conv_b_C"):
        return P(None)
    if name in ("A_log", "dt_bias", "D"):
        return P(MP) if ssm_heads_ok else P(None)
    if name == "w":  # MoE router
        return P(FSDP, None)
    if name in ("w_h", "w_gates"):  # CIFG-LSTM recurrent / legacy fused
        return P(FSDP, MP)
    if name == "b_gates":
        return P(MP)
    if name == "w_proj":
        return P(MP, FSDP)
    if name == "scale" or name == "bias":
        if len(names) >= 2 and names[-2] == "norm" and "mixer" in names:
            return P(MP)  # mamba gated-norm over sharded d_inner
        return P(*([None] * nd))
    return P(*([None] * nd))


def param_specs(params_shape, cfg: ModelConfig, mesh_cfg: MeshConfig):
    """Build the PartitionSpec tree mirroring an eval_shape'd param pytree."""
    mp = _axis_sizes(mesh_cfg)[MP]

    def one(path, leaf):
        names = _path_names(path)
        spec = _leaf_spec(names, leaf, cfg, mp)
        if names[0] in STACKED_ROOTS:
            spec = P(None, *spec)
        assert len(spec) == leaf.ndim, (names, spec, leaf.shape)
        return spec

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_specs(cfg: ModelConfig, shape: InputShape, mesh_cfg: MeshConfig,
                batch_size: int = None) -> Dict[str, Any]:
    """Input shardings for a global batch of ``shape``."""
    b = shape.global_batch if batch_size is None else batch_size
    dp = batch_axes(mesh_cfg)
    bspec = dp if b % batch_axis_size(mesh_cfg) == 0 else None
    out = {"tokens": P(bspec, None), "labels": P(bspec, None)}
    if cfg.family == "encdec":
        out["frames"] = P(bspec, None, None)
    if cfg.family == "vlm":
        out["image_embeds"] = P(bspec, None, None)
    return out


def cache_specs(cache_shape, cfg: ModelConfig, shape: InputShape,
                mesh_cfg: MeshConfig):
    """PartitionSpec tree for a decode cache pytree (from eval_shape)."""
    mp = _axis_sizes(mesh_cfg)[MP]
    dp = batch_axes(mesh_cfg)
    b = shape.global_batch
    bspec = dp if b % batch_axis_size(mesh_cfg) == 0 else None
    kv_ok = cfg.n_kv_heads % mp == 0
    seq_ok = shape.seq_len % mp == 0
    ssm_ok = cfg.ssm_heads % mp == 0 if cfg.ssm_heads else False
    di_ok = (cfg.ssm_expand * cfg.d_model) % mp == 0

    def one(path, leaf):
        name = _path_names(path)[-1]
        if name in ("k", "v"):
            if kv_ok:
                return P(None, bspec, None, MP, None)
            if seq_ok:
                return P(None, bspec, MP, None, None)
            return P(None, bspec, None, None, None)
        if name in ("xk", "xv"):  # whisper cross-attn memory (1500 frames)
            return P(None, bspec, None, None, None)
        if name == "ssm":
            return P(None, bspec, MP if ssm_ok else None, None, None)
        if name == "conv_x":
            return P(None, bspec, None, MP if di_ok else None)
        if name in ("conv_B", "conv_C"):
            return P(None, bspec, None, None)
        if name in ("h", "c"):  # lstm
            return P(bspec, None)
        if name == "pos":
            return P()
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def serving_param_specs(params_shape, cfg: ModelConfig, mesh_cfg: MeshConfig):
    """TP-only layout for serving (§Perf iteration B1): dropping the FSDP
    axis removes the per-decode-step weight all-gather entirely (measured
    −98% per-step collective bytes on phi3-mini decode_32k) at the cost of
    16× more param HBM per chip — use when weights/model_par fit beside the
    cache."""
    def drop(spec):
        def one(e):
            if e == FSDP:
                return None
            if isinstance(e, tuple):
                kept = tuple(a for a in e if a != FSDP)
                return kept if kept else None
            return e
        return P(*[one(e) for e in spec])

    return jax.tree_util.tree_map(drop, param_specs(params_shape, cfg,
                                                    mesh_cfg))
