"""repro: production-grade JAX reproduction of "Training Production Language
Models without Memorizing User Data" (Ramaswamy*, Thakkar* et al., 2020).

Top-level surface: DP-FedAvg (Algorithm 1), the RDP accountant, the Federated
Secret Sharer, a 10-architecture model zoo, and the multi-pod launch layer.
"""
__version__ = "1.0.0"
