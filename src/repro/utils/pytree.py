"""Pytree numeric helpers used across the DP machinery."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_global_norm(tree) -> jax.Array:
    """Global L2 norm across every leaf of a pytree (f32 accumulate)."""
    leaves = jax.tree_util.tree_leaves(tree)
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    return jnp.sqrt(sq)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda l: l * s.astype(l.dtype) if hasattr(s, "astype") else l * s, tree)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_zeros_like(tree, dtype=None):
    return jax.tree_util.tree_map(
        lambda l: jnp.zeros(l.shape, dtype or l.dtype), tree)


def tree_size(tree) -> int:
    return sum(l.size for l in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(lambda l: l.astype(dtype), tree)


def tree_noise(key, tree, std):
    """Gaussian noise pytree matching ``tree``'s shapes, always sampled in f32.

    DP noise MUST be f32: at the paper's σ=3.2e-5 the perturbation is below
    bf16 resolution near typical weight scales and would round away entirely.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noised = [jax.random.normal(k, l.shape, jnp.float32) * std for k, l in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, noised)
