"""JAX version compatibility shims.

The repo targets the current JAX API (``jax.set_mesh``,
``jax.sharding.get_abstract_mesh``, ``pltpu.CompilerParams``) but must also
run on the 0.4.x line baked into the CI image, where those names don't exist
yet. Every call site goes through this module so the version split lives in
exactly one place.
"""
from __future__ import annotations

import jax


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    New JAX: ``jax.set_mesh(mesh)``. Old JAX: a concrete ``Mesh`` is itself a
    context manager that sets ``thread_resources.env.physical_mesh``, which is
    what lets ``with_sharding_constraint`` accept bare ``PartitionSpec``s.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def get_abstract_mesh():
    """Ambient ``AbstractMesh`` or ``None`` when no mesh is in scope.

    Normalizes the two APIs: new JAX returns an (possibly ``empty``)
    ``AbstractMesh`` from ``jax.sharding.get_abstract_mesh``; on 0.4.x we
    read the legacy thread-local physical mesh installed by ``with mesh:``.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        mesh = fn()
        # 0.4.x exposes a same-named internal helper returning a tuple.
        if mesh is None or isinstance(mesh, tuple):
            mesh = None
        if mesh is not None:
            return mesh
    try:
        from jax._src import mesh as _mesh_lib
        physical = _mesh_lib.thread_resources.env.physical_mesh
        if physical is not None and not physical.empty:
            return physical.abstract_mesh
    except Exception:
        pass
    return None


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (new name) / ``pltpu.TPUCompilerParams`` (old)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_rep=False):
    """``jax.shard_map`` (new) / ``jax.experimental.shard_map.shard_map`` (old).

    ``check_rep`` defaults off: the engine's sharded round body closes over
    replicated population constants, which old-JAX rep-checking rejects.
    """
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        try:
            return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_rep)
        except TypeError:
            return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_rep)
