"""msgpack-based pytree checkpointing (no orbax in this environment).

Format: {"meta": {...}, "tree": nested dict with leaves as
{"__nd__": bytes, dtype, shape}}. Arrays round-trip exactly.
"""
from __future__ import annotations

import pathlib
from typing import Any, Dict, Tuple

import jax
import msgpack
import numpy as np


def _pack_leaf(x):
    a = np.asarray(x)
    return {b"__nd__": a.tobytes(), b"dtype": str(a.dtype).encode(),
            b"shape": list(a.shape)}


def _is_packed(d) -> bool:
    return isinstance(d, dict) and b"__nd__" in d


def _unpack_leaf(d):
    return np.frombuffer(d[b"__nd__"],
                         dtype=np.dtype(d[b"dtype"].decode())).reshape(
        d[b"shape"]).copy()


def _encode(tree):
    if isinstance(tree, dict):
        return {k: _encode(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return {"__seq__": [_encode(v) for v in tree],
                "__tuple__": isinstance(tree, tuple)}
    return _pack_leaf(tree)


def _decode(obj):
    if _is_packed(obj):
        return _unpack_leaf(obj)
    if isinstance(obj, dict):
        if "__seq__" in obj or b"__seq__" in obj:
            key = "__seq__" if "__seq__" in obj else b"__seq__"
            tkey = "__tuple__" if "__tuple__" in obj else b"__tuple__"
            seq = [_decode(v) for v in obj[key]]
            return tuple(seq) if obj.get(tkey) else seq
        return {(k.decode() if isinstance(k, bytes) else k): _decode(v)
                for k, v in obj.items()}
    return obj


def save(path, params, meta: Dict[str, Any] = None) -> None:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    host = jax.tree_util.tree_map(np.asarray, params)
    blob = msgpack.packb({"meta": meta or {}, "tree": _encode(host)},
                         use_bin_type=True)
    tmp = path.with_suffix(".tmp")
    tmp.write_bytes(blob)
    tmp.rename(path)  # atomic publish


def load(path) -> Tuple[Any, Dict[str, Any]]:
    obj = msgpack.unpackb(pathlib.Path(path).read_bytes(), raw=True,
                          strict_map_key=False)
    meta = {k.decode() if isinstance(k, bytes) else k:
            (v.decode() if isinstance(v, bytes) else v)
            for k, v in obj[b"meta"].items()}
    return _decode(obj[b"tree"]), meta
