"""msgpack-based pytree checkpointing (no orbax in this environment).

Format: {"meta": {...}, "tree": nested dict with leaves as
{"__nd__": bytes, dtype, shape}}. Arrays round-trip exactly.

:func:`load` applies :func:`migrate_lstm_gates`, the one-shot layout shim
for checkpoints written before the PR-5 CIFG param split: a fused
``w_gates (d+h, 3h)`` matrix is sliced into ``w_x (d, 3h)`` /
``w_h (h, 3h)`` (bytes unchanged — the split is a pure view change), so
old checkpoints keep loading into the current model.
"""
from __future__ import annotations

import os
import pathlib
import struct
from typing import Any, Dict, Tuple

import jax
import msgpack
import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint file exists but cannot be decoded (truncated write,
    corrupt bytes, or not a checkpoint at all). Raised by :func:`load` with
    the offending path in the message; a missing file stays a plain
    ``FileNotFoundError`` so callers can distinguish "resume from nothing"
    from "durable state is damaged"."""


def _pack_leaf(x):
    a = np.asarray(x)
    return {b"__nd__": a.tobytes(), b"dtype": str(a.dtype).encode(),
            b"shape": list(a.shape)}


def _is_packed(d) -> bool:
    return isinstance(d, dict) and b"__nd__" in d


def _unpack_leaf(d):
    return np.frombuffer(d[b"__nd__"],
                         dtype=np.dtype(d[b"dtype"].decode())).reshape(
        d[b"shape"]).copy()


def _encode(tree):
    if isinstance(tree, dict):
        return {k: _encode(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return {"__seq__": [_encode(v) for v in tree],
                "__tuple__": isinstance(tree, tuple)}
    return _pack_leaf(tree)


def _decode(obj):
    if _is_packed(obj):
        return _unpack_leaf(obj)
    if isinstance(obj, dict):
        if "__seq__" in obj or b"__seq__" in obj:
            key = "__seq__" if "__seq__" in obj else b"__seq__"
            tkey = "__tuple__" if "__tuple__" in obj else b"__tuple__"
            seq = [_decode(v) for v in obj[key]]
            return tuple(seq) if obj.get(tkey) else seq
        return {(k.decode() if isinstance(k, bytes) else k): _decode(v)
                for k, v in obj.items()}
    return obj


def save(path, params, meta: Dict[str, Any] = None) -> None:
    """Write atomically: serialize to a same-directory temp file, fsync,
    then ``os.replace`` onto ``path``. A crash at any point leaves either
    the previous durable file or the complete new one — never a torn
    write."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    host = jax.tree_util.tree_map(np.asarray, params)
    blob = msgpack.packb({"meta": meta or {}, "tree": _encode(host)},
                         use_bin_type=True)
    tmp = path.with_name(f".{path.name}.tmp.{os.getpid()}")
    try:
        with open(tmp, "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic publish
    except BaseException:
        tmp.unlink(missing_ok=True)
        raise


def migrate_lstm_gates(tree):
    """Pre-PR-5 CIFG-LSTM layout shim: split a fused ``w_gates (d+h, 3h)``
    leaf into ``w_x (d, 3h)`` (rows [:d]) and ``w_h (h, 3h)`` (rows [d:]) —
    the dims are recovered from the shape alone (3h = n_cols ⇒ h, then
    d = n_rows − h). Walks nested dicts/sequences; dicts that already carry
    the split layout are left untouched. Idempotent."""
    if isinstance(tree, dict):
        tree = {k: migrate_lstm_gates(v) for k, v in tree.items()}
        wg = tree.get("w_gates")
        if (wg is not None and "w_x" not in tree and "w_h" not in tree
                and getattr(wg, "ndim", 0) == 2 and wg.shape[1] % 3 == 0
                and wg.shape[0] > wg.shape[1] // 3):
            h = wg.shape[1] // 3
            del tree["w_gates"]
            tree["w_x"], tree["w_h"] = wg[:-h], wg[-h:]
        return tree
    if isinstance(tree, list):
        return [migrate_lstm_gates(v) for v in tree]
    if isinstance(tree, tuple):
        return tuple(migrate_lstm_gates(v) for v in tree)
    return tree


def load(path) -> Tuple[Any, Dict[str, Any]]:
    path = pathlib.Path(path)
    blob = path.read_bytes()   # missing file → plain FileNotFoundError
    try:
        obj = msgpack.unpackb(blob, raw=True, strict_map_key=False)
        meta = {k.decode() if isinstance(k, bytes) else k:
                (v.decode() if isinstance(v, bytes) else v)
                for k, v in obj[b"meta"].items()}
        return migrate_lstm_gates(_decode(obj[b"tree"])), meta
    except (ValueError, KeyError, TypeError, IndexError, struct.error,
            msgpack.exceptions.UnpackException,
            msgpack.exceptions.ExtraData) as e:
        raise CheckpointError(
            f"corrupt or truncated checkpoint {path}: "
            f"{type(e).__name__}: {e}") from e
