"""Pallas TPU kernel for the Mamba-2 chunked SSD scan [arXiv:2405.21060].

TPU adaptation of the paper's "state-space duality": within a chunk of Q
tokens the recurrence is evaluated in its dual quadratic (attention-like)
form — three (Q×Q)/(Q×N)/(Q×p) matmuls that run on the MXU — while a
(p × N) state carried in VMEM scratch propagates the recurrence across
chunks. Grid (B, H, n_chunks), chunk dim innermost/sequential.

This replaces the GPU implementation's warp-level chunk scan: on TPU the
inter-chunk dependency is expressed through scratch persistence across the
sequential grid dimension instead of shared-memory accumulators.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils.compat import tpu_compiler_params

CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, state_out_ref,
                state_ref):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (Q, p)
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # (Q,)
    Bm = b_ref[0].astype(jnp.float32)              # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)              # (Q, N)
    A = a_ref[0]                                   # scalar (negative)

    Q = x.shape[0]
    a = dt * A                                     # (Q,)
    cum = jnp.cumsum(a)                            # (Q,)
    # intra-chunk dual (quadratic) form
    seg = cum[:, None] - cum[None, :]              # (Q, Q)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    Lmat = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    w = scores * Lmat * dt[None, :]
    y_intra = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # inter-chunk: contribution of the carried state
    state = state_ref[...]                         # (p, N)
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)        # (Q, p)
    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)
    # state update
    decay_out = jnp.exp(cum[-1] - cum)             # (Q,)
    dB = (dt * decay_out)[:, None] * Bm            # (Q, N)
    state_ref[...] = jnp.exp(cum[-1]) * state + jax.lax.dot_general(
        x, dB, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ic == pl.num_programs(2) - 1)
    def _done():
        state_out_ref[0, 0] = state_ref[...]


def ssd_scan_kernel(x, dt, Bm, Cm, A, *, interpret: bool = True):
    """x: (B,S,H,p); dt: (B,S,H) f32; Bm,Cm: (B,S,N); A: (H,) f32 (negative).
    S must be a multiple of CHUNK. Returns (y (B,S,H,p) f32,
    final_state (B,H,p,N) f32)."""
    Bsz, S, H, p = x.shape
    N = Bm.shape[-1]
    assert S % CHUNK == 0, (S, CHUNK)
    grid = (Bsz, H, S // CHUNK)
    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, CHUNK, 1, p), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, CHUNK, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, CHUNK, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, CHUNK, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,), memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, CHUNK, 1, p), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, p, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, H, p), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, H, p, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, Bm, Cm, A)
