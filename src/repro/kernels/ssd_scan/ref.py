"""Pure-jnp oracle: sequential (token-by-token) SSD recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, Bm, Cm, A, h0=None):
    """Sequential evaluation of h_t = e^{A·dt_t} h_{t-1} + dt_t·B_t⊗x_t,
    y_t = C_t·h_t. Same shapes as the kernel. Returns (y, final_state)."""
    Bsz, S, H, p = x.shape
    N = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, p, N), jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp   # (B,H,p), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * A[None, :])                      # (B,H)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt)
        h = decay[:, :, None, None] * h + dBx
        y = jnp.einsum("bn,bhpn->bhp", ct, h)
        return h, y

    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2).astype(jnp.float32),
          Cm.transpose(1, 0, 2).astype(jnp.float32))
    h_fin, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3), h_fin
