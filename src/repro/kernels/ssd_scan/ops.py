"""jit'd wrapper for the chunked SSD Pallas kernel (pads S to CHUNK)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import CHUNK, ssd_scan_kernel


@partial(jax.jit, static_argnames=("interpret",))
def ssd_scan(x, dt, Bm, Cm, A, *, interpret: bool = True):
    """x: (B,S,H,p); dt: (B,S,H); Bm,Cm: (B,S,N); A: (H,).
    Returns (y (B,S,H,p) f32, final_state (B,H,p,N) f32)."""
    S = x.shape[1]
    pad = (-S) % CHUNK
    if pad:
        widths = lambda nd: [(0, pad) if i == 1 else (0, 0) for i in range(nd)]
        x = jnp.pad(x, widths(4))
        dt = jnp.pad(dt, widths(3))   # dt=0 ⇒ identity recurrence on padding
        Bm = jnp.pad(Bm, widths(3))
        Cm = jnp.pad(Cm, widths(3))
    y, state = ssd_scan_kernel(x.astype(jnp.float32), dt.astype(jnp.float32),
                               Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                               A.astype(jnp.float32), interpret=interpret)
    if pad:
        y = y[:, :S]
    return y, state
