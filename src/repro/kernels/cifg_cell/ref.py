"""Pure-jnp reference for the fused CIFG recurrent cell.

This is the oracle the Pallas kernels (`cifg_cell.py` via `ops.cifg_step`)
are validated against, and the `cell_path="ref"` model path: the post-split
recurrent step where the input projection ``zx = x_t @ w_x + b`` has already
been hoisted out of the time scan (it is h-independent, so all timesteps can
be computed in one large GEMM), leaving only the small hidden-state matmul
``h @ w_h`` plus the gate nonlinearities and state update per step.

CIFG couples the input and forget gates (i = 1 − f) [SSB14], so there are
three gate blocks packed along the last axis of ``zx`` / ``w_h``:
``[f | o | g]``, each ``hidden`` wide.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cifg_cell_ref(zx, h, c, w_h, *, compute_dtype=None):
    """One CIFG step given the hoisted input projection.

    zx: (B, 3H) f32 — ``x_t @ w_x + b_gates`` for this timestep;
    h, c: (B, H) f32 — previous hidden / cell state;
    w_h: (H, 3H) — recurrent gate matrix (param dtype).
    ``compute_dtype`` is the matmul dtype (the model's ``cfg.compute_dtype``);
    the gate math and state update stay f32. Returns (h_new, c_new) f32.
    """
    cd = jnp.dtype(compute_dtype) if compute_dtype is not None else w_h.dtype
    hidden = h.shape[-1]
    z = zx + jnp.dot(h.astype(cd), w_h.astype(cd),
                     preferred_element_type=jnp.float32)
    f = jax.nn.sigmoid(z[..., :hidden] + 1.0)   # forget-bias 1
    o = jax.nn.sigmoid(z[..., hidden:2 * hidden])
    g = jnp.tanh(z[..., 2 * hidden:])
    c_new = f * c + (1.0 - f) * g               # CIFG: i = 1 − f
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new
