"""jit-friendly wrapper: the fused CIFG recurrent step with a custom VJP.

``cifg_step(zx, h, c, w_h)`` takes the model's natural shapes — ``zx``
``(B, 3H)`` (the timestep's slice of the hoisted input projection
``x @ w_x + b_gates``), state ``h``/``c`` ``(B, H)``, and the recurrent
matrix ``w_h (H, 3H)`` — packs the three gate blocks into the kernels'
stacked layout, pads ``B``/``H`` up to the (8, 128) tile grid, and runs the
fused Pallas forward; the backward pass runs the fused recompute kernel
(`cifg_cell.cell_bwd`) via ``jax.custom_vjp``, so local SGD's gradient
step stays on the fused path too.

Padding is exact: padded ``h``/``c`` columns and ``w_h`` rows are zero, so
real gate columns see unchanged matmul results, and padded batch rows have
zero cotangents in the backward, so they contribute nothing to ``dw_h``.

``interpret=None`` auto-selects per backend (compiled Pallas on TPU, the
Pallas interpreter elsewhere); both the op and its VJP batch cleanly under
``vmap`` (the engine vmaps the client chunk axis over the whole loss
gradient) and compose with ``jax.checkpoint`` (the model's ``remat`` knob).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.cifg_cell import cifg_cell as K


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _pack_gates(a, hidden: int, rows_pad: int, lanes_pad: int):
    """(rows, 3H) → (3, rows_pad, lanes_pad): split the packed gate axis
    into a stacked leading dim and zero-pad the minor tile dims."""
    rows = a.shape[0]
    a3 = a.reshape(rows, 3, hidden).transpose(1, 0, 2)
    return jnp.pad(a3, ((0, 0), (0, rows_pad - rows),
                        (0, lanes_pad - hidden)))


def _unpack_gates(a3, rows: int, hidden: int):
    """(3, rows_pad, lanes_pad) → (rows, 3H): inverse of `_pack_gates`."""
    return a3[:, :rows, :hidden].transpose(1, 0, 2).reshape(rows, 3 * hidden)


def _pad2(a, rows_pad: int, lanes_pad: int):
    return jnp.pad(a, ((0, rows_pad - a.shape[0]),
                       (0, lanes_pad - a.shape[1])))


def _prep(zx, h, c, w_h, compute_dtype):
    B, H = h.shape
    Bp, Hp = _round_up(B, K.SUBLANES), _round_up(H, K.LANES)
    cd = jnp.dtype(compute_dtype) if compute_dtype is not None else w_h.dtype
    zx3 = _pack_gates(zx.astype(jnp.float32), H, Bp, Hp)
    wh3 = _pack_gates(w_h, H, Hp, Hp).astype(cd)
    hp = _pad2(h.astype(jnp.float32), Bp, Hp)
    cp = _pad2(c.astype(jnp.float32), Bp, Hp)
    return zx3, wh3, hp, cp


@partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _cifg_step(zx, h, c, w_h, compute_dtype, interpret):
    B, H = h.shape
    zx3, wh3, hp, cp = _prep(zx, h, c, w_h, compute_dtype)
    hn, cn = K.cell_fwd(zx3, wh3, hp, cp, interpret=interpret)
    return hn[:B, :H], cn[:B, :H]


def _cifg_step_fwd(zx, h, c, w_h, compute_dtype, interpret):
    return (_cifg_step(zx, h, c, w_h, compute_dtype, interpret),
            (zx, h, c, w_h))


def _cifg_step_bwd(compute_dtype, interpret, res, grads):
    zx, h, c, w_h = res
    dh_new, dc_new = grads
    B, H = h.shape
    Bp, Hp = _round_up(B, K.SUBLANES), _round_up(H, K.LANES)
    zx3, wh3, hp, cp = _prep(zx, h, c, w_h, compute_dtype)
    dhp = _pad2(dh_new.astype(jnp.float32), Bp, Hp)
    dcp = _pad2(dc_new.astype(jnp.float32), Bp, Hp)
    dzx3, dh, dc, dwh3 = K.cell_bwd(zx3, wh3, hp, cp, dhp, dcp,
                                    interpret=interpret)
    return (_unpack_gates(dzx3, B, H).astype(zx.dtype),
            dh[:B, :H].astype(h.dtype), dc[:B, :H].astype(c.dtype),
            _unpack_gates(dwh3, H, H).astype(w_h.dtype))


_cifg_step.defvjp(_cifg_step_fwd, _cifg_step_bwd)


# ---------------------------------------------------------------- sequence


def _seq_scan(zx, h0, c0, w_h, cell: str, cd, interpret):
    """Run the forward recurrence over the whole sequence.

    zx: (S, B, 3H) f32 time-major hoisted input projections; returns the full
    state stacks (hs, cs), each (S, B, H) f32. ``cell="fused"`` steps through
    the Pallas `cifg_cell.cell_fwd` kernel with the tile padding done *once*
    outside the scan; ``cell="seq"`` steps through the pure-jnp reference
    cell."""
    from repro.kernels.cifg_cell.ref import cifg_cell_ref

    S, B, threeH = zx.shape
    H = threeH // 3
    if cell == "fused":
        Bp, Hp = _round_up(B, K.SUBLANES), _round_up(H, K.LANES)
        cdt = jnp.dtype(cd) if cd is not None else w_h.dtype
        zx3 = jax.vmap(lambda a: _pack_gates(a, H, Bp, Hp))(
            zx.astype(jnp.float32))                       # (S, 3, Bp, Hp)
        wh3 = _pack_gates(w_h, H, Hp, Hp).astype(cdt)
        hp = _pad2(h0.astype(jnp.float32), Bp, Hp)
        cp = _pad2(c0.astype(jnp.float32), Bp, Hp)

        def step(carry, zx3_t):
            h, c = K.cell_fwd(zx3_t, wh3, carry[0], carry[1],
                              interpret=interpret)
            return (h, c), (h, c)

        _, (hs, cs) = jax.lax.scan(step, (hp, cp), zx3)
        return hs[:, :B, :H], cs[:, :B, :H]

    def step(carry, zx_t):
        h, c = cifg_cell_ref(zx_t, carry[0], carry[1], w_h, compute_dtype=cd)
        return (h, c), (h, c)

    _, (hs, cs) = jax.lax.scan(step, (h0, c0), zx)
    return hs, cs


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _cifg_sequence(zx, h0, c0, w_h, cell, cd, remat, interpret):
    hs, cs = _seq_scan(zx, h0, c0, w_h, cell, cd, interpret)
    return hs, (hs[-1], cs[-1])


def _cifg_sequence_fwd(zx, h0, c0, w_h, cell, cd, remat, interpret):
    hs, cs = _seq_scan(zx, h0, c0, w_h, cell, cd, interpret)
    saved = None if remat else (hs, cs)
    return (hs, (hs[-1], cs[-1])), (zx, h0, c0, w_h, saved)


def _cifg_sequence_bwd(cell, cd, remat, interpret, res, ct):
    """Time-fused reverse pass. Everything that does not depend on the
    sequential (dh, dc) recursion is hoisted out of the reverse scan and
    batched over time: the gate recompute is ONE (S·B, H) @ (H, 3H) GEMM
    plus batched elementwise factor precomputes, and the ``dw_h``
    reduction is ONE (H, S·B) @ (S·B, 3H) GEMM after the scan. The only
    per-step work left is the elementwise (dh, dc) update and the single
    small ``dz @ w_h^T`` matmul. The whole reverse pass runs in f32
    (cotangent precision is a backward-only choice — it does not touch the
    forward trajectory)."""
    zx, h0, c0, w_h, saved = res
    dhs, (dhf, dcf) = ct
    hs, cs = (saved if saved is not None
              else _seq_scan(zx, h0, c0, w_h, cell, cd, interpret))
    S, B, H = hs.shape
    h_prev = jnp.concatenate([h0[None], hs[:-1]])
    c_prev = jnp.concatenate([c0[None], cs[:-1]])
    cdt = jnp.dtype(cd) if cd is not None else w_h.dtype
    # batched gate recompute — one GEMM over all timesteps, accumulated in
    # f32 (preferred_element_type) exactly like the forward cell, so the
    # recomputed linearization point matches the forward's under bf16
    z = zx + jnp.dot(h_prev.reshape(S * B, H).astype(cdt), w_h.astype(cdt),
                     preferred_element_type=jnp.float32
                     ).reshape(S, B, 3 * H)
    f = jax.nn.sigmoid(z[..., :H] + 1.0)
    o = jax.nn.sigmoid(z[..., H:2 * H])
    g = jnp.tanh(z[..., 2 * H:])
    t = jnp.tanh(cs)
    # per-step cotangent factors, precomputed batched:
    #   dct = dc + dh·A;  dzf = dct·Bf;  dzo = dh·Co;  dzg = dct·Dg
    A = o * (1.0 - t * t)
    Bf = (c_prev - g) * f * (1.0 - f)
    Co = t * o * (1.0 - o)
    Dg = (1.0 - f) * (1.0 - g * g)
    whT = w_h.astype(jnp.float32).T

    def rev(carry, inp):
        dh_next, dc_next = carry
        dhs_t, A_t, Bf_t, Co_t, Dg_t, f_t = inp
        dh = dh_next + dhs_t
        dct = dc_next + dh * A_t
        dz = jnp.concatenate([dct * Bf_t, dh * Co_t, dct * Dg_t], axis=-1)
        return (dz @ whT, dct * f_t), dz

    (dh0, dc0), dz = jax.lax.scan(rev, (dhf.astype(jnp.float32),
                                        dcf.astype(jnp.float32)),
                                  (dhs.astype(jnp.float32), A, Bf, Co, Dg, f),
                                  reverse=True)
    # dw_h = Σ_t h_prev_t^T @ dz_t — one GEMM over the stacked time axis
    dwh = jax.lax.dot_general(
        h_prev.reshape(S * B, H), dz.reshape(S * B, 3 * H),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    return dz.astype(zx.dtype), dh0, dc0, dwh.astype(w_h.dtype)


_cifg_sequence.defvjp(_cifg_sequence_fwd, _cifg_sequence_bwd)


def cifg_sequence(zx, h0, c0, w_h, *, cell: str = "seq", compute_dtype=None,
                  remat: bool = False, interpret=None):
    """Whole-sequence CIFG recurrence with a time-fused backward.

    zx: (S, B, 3H) f32 — time-major hoisted input projections
    (``x @ w_x + b_gates`` for every timestep, one GEMM upstream);
    h0, c0: (B, H) f32; w_h: (H, 3H). Returns ``(hs (S, B, H) f32,
    (h_fin, c_fin))``.

    ``cell`` selects the forward step: ``"fused"`` = the Pallas
    `cifg_cell.cell_fwd` kernel (tile padding hoisted out of the scan;
    compiled on TPU, interpreter elsewhere), ``"seq"`` = the pure-jnp cell.
    Both share the custom time-fused VJP (`_cifg_sequence_bwd`): gate
    recompute, cotangent factors, and the ``dw_h`` reduction are batched
    over time outside the reverse scan, which keeps only the sequential
    elementwise state update plus one small matmul per step. ``remat=True``
    drops the state stacks from the residuals and recomputes them in the
    backward (the scan-step checkpointing knob).
    """
    if zx.ndim != 3 or h0.ndim != 2 or c0.shape != h0.shape \
            or zx.shape[1:] != (h0.shape[0], 3 * h0.shape[1]) \
            or w_h.shape != (h0.shape[1], 3 * h0.shape[1]):
        raise ValueError(
            f"cifg_sequence: expected zx (S, B, 3H), h0/c0 (B, H), "
            f"w_h (H, 3H) — got zx {tuple(zx.shape)}, h0 {tuple(h0.shape)}, "
            f"c0 {tuple(c0.shape)}, w_h {tuple(w_h.shape)}")
    if cell not in ("fused", "seq"):
        raise ValueError(f"cell must be 'fused' or 'seq', got {cell!r}")
    if interpret is None:
        interpret = K.default_interpret()
    cd = str(jnp.dtype(compute_dtype)) if compute_dtype is not None else None
    return _cifg_sequence(zx, h0, c0, w_h, cell, cd, bool(remat),
                          bool(interpret))


def cifg_states(zx, h0, c0, w_h, *, cell: str = "seq", compute_dtype=None,
                interpret=None):
    """Forward-only whole-sequence CIFG recurrence returning the **full**
    state stacks ``(hs, cs)``, each (S, B, H) f32 — the building block of
    the length-aware (bucket-padded) prefill: gather ``(hs[t], cs[t])`` to
    read the state *as of step t*.

    Shares the per-step forward math with :func:`cifg_sequence` (both run
    `_seq_scan`), and the ``"seq"`` cell's step *is* `ref.cifg_cell_ref` —
    so for every cell path, ``(hs[t], cs[t])`` of a right-padded run is
    bit-identical to the final state of an unpadded run of length ``t+1``
    (the scan is causal; padding steps only execute after ``t``). No
    custom VJP — this is an inference-path op (differentiate through
    :func:`cifg_sequence` instead)."""
    if zx.ndim != 3 or h0.ndim != 2 or c0.shape != h0.shape \
            or zx.shape[1:] != (h0.shape[0], 3 * h0.shape[1]) \
            or w_h.shape != (h0.shape[1], 3 * h0.shape[1]):
        raise ValueError(
            f"cifg_states: expected zx (S, B, 3H), h0/c0 (B, H), "
            f"w_h (H, 3H) — got zx {tuple(zx.shape)}, h0 {tuple(h0.shape)}, "
            f"c0 {tuple(c0.shape)}, w_h {tuple(w_h.shape)}")
    if cell not in ("fused", "seq"):
        raise ValueError(f"cell must be 'fused' or 'seq', got {cell!r}")
    if interpret is None:
        interpret = K.default_interpret()
    cd = str(jnp.dtype(compute_dtype)) if compute_dtype is not None else None
    return _seq_scan(zx, h0, c0, w_h, cell, cd, bool(interpret))


def cifg_step(zx, h, c, w_h, *, compute_dtype=None, interpret=None):
    """Fused CIFG recurrent step (forward + custom fused backward).

    zx: (B, 3H) f32 — hoisted input projection for this timestep;
    h, c: (B, H) f32 — previous state; w_h: (H, 3H) — recurrent matrix.
    ``compute_dtype`` is the matmul dtype (the model's ``cfg.compute_dtype``;
    ``None`` = ``w_h.dtype``); gate math and the state update stay f32.
    Returns (h_new, c_new) f32 — numerically equivalent (not bit-equal) to
    `ref.cifg_cell_ref`.
    """
    if zx.ndim != 2 or h.ndim != 2 or c.shape != h.shape \
            or w_h.ndim != 2 or zx.shape != (h.shape[0], 3 * h.shape[1]) \
            or w_h.shape != (h.shape[1], 3 * h.shape[1]):
        raise ValueError(
            f"cifg_step: expected zx (B, 3H), h/c (B, H), w_h (H, 3H) — got "
            f"zx {tuple(zx.shape)}, h {tuple(h.shape)}, c {tuple(c.shape)}, "
            f"w_h {tuple(w_h.shape)}")
    if interpret is None:
        interpret = K.default_interpret()
    cd = str(jnp.dtype(compute_dtype)) if compute_dtype is not None else None
    return _cifg_step(zx, h, c, w_h, cd, bool(interpret))
