from repro.kernels.cifg_cell.ops import cifg_sequence, cifg_states, cifg_step
from repro.kernels.cifg_cell.ref import cifg_cell_ref
