"""Pallas TPU kernels for the CIFG-LSTM recurrent cell — the client-step
hot spot of the DP-FedAvg simulation (local SGD runs this cell S times per
batch, forward *and* backward, for every client in the cohort).

After the PR-5 param split the input projection ``zx = x @ w_x + b`` is
hoisted out of the time scan (one large h-independent GEMM over all
timesteps), so the only per-step work left is ``z = zx_t + h @ w_h`` plus
the gate nonlinearities and the state update. Done as separate XLA ops that
is four HBM round-trips of the (B, 3H) gate block per step; these kernels
fuse the whole step — three small MXU matmuls plus the VPU gate math —
into one VMEM-resident pass, and the backward kernel fuses the
recompute-and-accumulate reverse step the same way.

Layout: the three CIFG gate blocks ``[f | o | g]`` are carried as a stacked
leading axis — ``zx3 (3, B, H)``, ``wh3 (3, H, H)`` — so every operand's
minor two dims are plain ``(rows, H)`` tiles: ``H`` a multiple of 128
(lanes), rows a multiple of 8 (sublanes). `ops.cifg_step` is the supported
padding/packing path; ragged shapes fail loudly here.

``interpret=None`` (default) auto-selects per backend: compiled Pallas on
TPU, the Pallas interpreter elsewhere — same policy as `kernels.dp_clip`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128   # minor-most dim: H padded to a multiple of this
SUBLANES = 8  # second-minor dim: batch rows padded to a multiple of this


def default_interpret() -> bool:
    """Backend auto-select: real Pallas on TPU, interpreter elsewhere."""
    return jax.default_backend() != "tpu"


def _check_cell(name: str, zx3, wh3, h, c) -> None:
    """The kernels run one un-gridded VMEM block per call — a ragged
    operand would violate the (8, 128) tile constraints on TPU. Fail
    loudly at trace time (`ops.cifg_step` is the supported padding path)."""
    B, H = h.shape[-2:] if h.ndim >= 2 else (0, 0)
    ok = (h.ndim == 2 and c.shape == h.shape
          and zx3.shape == (3, B, H) and wh3.shape == (3, H, H)
          and B % SUBLANES == 0 and H % LANES == 0)
    if not ok:
        raise ValueError(
            f"{name}: operands must be the packed gate layout zx3 (3, B, H),"
            f" wh3 (3, H, H), h/c (B, H) with B % {SUBLANES} == 0 and "
            f"H % {LANES} == 0 (see repro.kernels.cifg_cell.ops.cifg_step "
            f"for the padding path) — got zx3 {tuple(zx3.shape)}, wh3 "
            f"{tuple(wh3.shape)}, h {tuple(h.shape)}, c {tuple(c.shape)}")


def _gates(zx3, wh3, h, c):
    """Shared fwd recompute: returns (f, o, g, c_new, tanh(c_new))."""
    cd = wh3.dtype
    hc = h.astype(cd)
    zf = zx3[0] + jnp.dot(hc, wh3[0], preferred_element_type=jnp.float32)
    zo = zx3[1] + jnp.dot(hc, wh3[1], preferred_element_type=jnp.float32)
    zg = zx3[2] + jnp.dot(hc, wh3[2], preferred_element_type=jnp.float32)
    f = jax.nn.sigmoid(zf + 1.0)                # forget-bias 1
    o = jax.nn.sigmoid(zo)
    g = jnp.tanh(zg)
    c_new = f * c + (1.0 - f) * g               # CIFG: i = 1 − f
    return f, o, g, c_new, jnp.tanh(c_new)


def _fwd_kernel(zx3_ref, wh3_ref, h_ref, c_ref, h_out, c_out):
    _, o, _, c_new, t = _gates(zx3_ref[...], wh3_ref[...],
                               h_ref[...], c_ref[...])
    h_out[...] = o * t
    c_out[...] = c_new


def cell_fwd(zx3, wh3, h, c, *, interpret=None):
    """Fused CIFG step on the packed gate layout → (h_new, c_new) f32."""
    _check_cell("cell_fwd", zx3, wh3, h, c)
    if interpret is None:
        interpret = default_interpret()
    out = jax.ShapeDtypeStruct(h.shape, jnp.float32)
    return pl.pallas_call(
        _fwd_kernel,
        in_specs=[pl.BlockSpec(memory_space=pltpu.VMEM)] * 4,
        out_specs=(pl.BlockSpec(memory_space=pltpu.VMEM),) * 2,
        out_shape=(out, out),
        interpret=interpret,
    )(zx3, wh3, h, c)


def _bwd_kernel(zx3_ref, wh3_ref, h_ref, c_ref, dh_ref, dc_ref,
                dzx3_out, dh_out, dc_out, dwh3_out):
    zx3, wh3 = zx3_ref[...], wh3_ref[...]
    h, c = h_ref[...], c_ref[...]
    dh_new, dc_new = dh_ref[...], dc_ref[...]
    f, o, g, _, t = _gates(zx3, wh3, h, c)
    do = dh_new * t
    dct = dc_new + dh_new * o * (1.0 - t * t)   # ∂L/∂c_new (total)
    dzf = dct * (c - g) * f * (1.0 - f)
    dzo = do * o * (1.0 - o)
    dzg = dct * (1.0 - f) * (1.0 - g * g)
    dzx3_out[0, :, :] = dzf
    dzx3_out[1, :, :] = dzo
    dzx3_out[2, :, :] = dzg
    cd = wh3.dtype
    # dh = Σ_k dz_k @ wh_k^T — contract the gate-output dim of both operands
    tr = (((1,), (1,)), ((), ()))
    dh_out[...] = sum(
        jax.lax.dot_general(dz.astype(cd), wh3[k], tr,
                            preferred_element_type=jnp.float32)
        for k, dz in enumerate((dzf, dzo, dzg)))
    dc_out[...] = dct * f
    # dwh_k = h^T @ dz_k — contract the batch dim of both operands
    bt = (((0,), (0,)), ((), ()))
    hc = h.astype(cd)
    for k, dz in enumerate((dzf, dzo, dzg)):
        dwh3_out[k, :, :] = jax.lax.dot_general(
            hc, dz.astype(cd), bt, preferred_element_type=jnp.float32)


def cell_bwd(zx3, wh3, h, c, dh_new, dc_new, *, interpret=None):
    """Fused reverse step: recompute the gates, return
    (dzx3 (3,B,H), dh (B,H), dc (B,H), dwh3 (3,H,H)) in f32."""
    _check_cell("cell_bwd", zx3, wh3, h, c)
    if dh_new.shape != h.shape or dc_new.shape != c.shape:
        raise ValueError(
            f"cell_bwd: cotangents must match the state shape "
            f"{tuple(h.shape)}, got dh {tuple(dh_new.shape)}, "
            f"dc {tuple(dc_new.shape)}")
    if interpret is None:
        interpret = default_interpret()
    st = jax.ShapeDtypeStruct(h.shape, jnp.float32)
    return pl.pallas_call(
        _bwd_kernel,
        in_specs=[pl.BlockSpec(memory_space=pltpu.VMEM)] * 6,
        out_specs=(pl.BlockSpec(memory_space=pltpu.VMEM),) * 4,
        out_shape=(jax.ShapeDtypeStruct(zx3.shape, jnp.float32), st, st,
                   jax.ShapeDtypeStruct(wh3.shape, jnp.float32)),
        interpret=interpret,
    )(zx3, wh3, h, c, dh_new, dc_new)
