"""Pallas TPU kernels for the DP-FedAvg hot-spot: per-user update clipping.

Clipping a user update on a model-sharded mesh is (a) a global sum of
squares over the flat update, then (b) an elementwise `acc += factor · Δ`
accumulate into the round's clipped-update sum. Done naively that is three
HBM round-trips of the flat vector per client per round; these kernels fuse
each pass into single-sweep VMEM-tiled reductions/updates.

Tiles are (ROWS, 128) f32 — lane-dim 128, sublane a multiple of 8 — so the
VPU operates on full native registers. The sum-of-squares kernel keeps a
scalar accumulator in SMEM across the sequential grid; the accumulate kernel
is a pure elementwise fused multiply-add.

``interpret=None`` (the default) auto-selects per backend: compiled Pallas
on TPU, interpret mode everywhere else (CPU executes the same kernel bodies
through the Pallas interpreter — numerically identical, so the simulation
engine's streaming accumulator runs the *same* clip→accumulate code path it
will run on hardware).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
ROWS = 256          # 256×128 f32 tile = 128 KiB, comfortably inside VMEM
TILE = ROWS * LANES


def default_interpret() -> bool:
    """Backend auto-select: real Pallas on TPU, interpreter elsewhere."""
    return jax.default_backend() != "tpu"


def _check_tiled(name: str, x2d) -> None:
    """The kernels sweep (ROWS, LANES) tiles over a sequential grid — a
    ragged input would silently read out of the last block. Fail loudly at
    trace time instead (`ops._to_tiles` is the supported padding path)."""
    if x2d.ndim != 2 or x2d.shape[-1] != LANES or x2d.shape[0] % ROWS:
        raise ValueError(
            f"{name}: input must be 2-D (k·{ROWS}, {LANES}) — the padded "
            f"flat-vector tile layout (TILE={TILE} elements; see "
            f"repro.kernels.dp_clip.ops._to_tiles) — got shape "
            f"{tuple(x2d.shape)}")


def _sumsq_kernel(x_ref, out_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[0] = 0.0

    x = x_ref[...].astype(jnp.float32)
    acc_ref[0] += jnp.sum(x * x)

    @pl.when(i == pl.num_programs(0) - 1)
    def _done():
        out_ref[0] = acc_ref[0]


def sumsq(x2d, *, interpret=None):
    """x2d: (n_tiles·ROWS, LANES) f32 → scalar sum of squares."""
    _check_tiled("sumsq", x2d)
    if interpret is None:
        interpret = default_interpret()
    n = x2d.shape[0] // ROWS
    return pl.pallas_call(
        _sumsq_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
    )(x2d)[0]


def _clip_acc_kernel(factor_ref, delta_ref, acc_ref, out_ref):
    out_ref[...] = acc_ref[...] + factor_ref[0] * delta_ref[...].astype(jnp.float32)


def clip_accumulate_2d(acc2d, delta2d, factor, *, interpret=None):
    """out = acc + factor · delta, single fused sweep. All (R·ROWS, LANES)."""
    _check_tiled("clip_accumulate_2d", acc2d)
    _check_tiled("clip_accumulate_2d", delta2d)
    if acc2d.shape != delta2d.shape:
        raise ValueError(
            f"clip_accumulate_2d: acc and delta must share one tile layout, "
            f"got {tuple(acc2d.shape)} vs {tuple(delta2d.shape)}")
    if interpret is None:
        interpret = default_interpret()
    n = acc2d.shape[0] // ROWS
    return pl.pallas_call(
        _clip_acc_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(acc2d.shape, jnp.float32),
        interpret=interpret,
    )(factor.reshape(1), delta2d, acc2d)
