from repro.kernels.dp_clip.ops import clip_accumulate, fused_sumsq
