"""Pure-jnp oracle for the dp_clip kernels."""
from __future__ import annotations

import jax.numpy as jnp


def sumsq_ref(x):
    return jnp.sum(jnp.square(x.astype(jnp.float32)))


def clip_accumulate_ref(acc, delta, factor):
    return acc.astype(jnp.float32) + factor * delta.astype(jnp.float32)


def clip_factor_ref(sumsq, clip_norm: float):
    norm = jnp.sqrt(sumsq)
    return jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-12))
