"""jit'd wrappers: pytree-level fused clip-and-accumulate.

``fused_sumsq(tree)`` / ``clip_accumulate(acc_tree, delta_tree, factor)``
flatten each leaf, pad to the (ROWS·LANES) tile, and run the Pallas kernels;
`interpret=True` executes the kernel bodies on CPU for validation (TPU is
the compile target).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.dp_clip import dp_clip as K
from repro.kernels.dp_clip.ref import clip_factor_ref


def _to_tiles(leaf):
    flat = leaf.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % K.TILE
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, K.LANES)


@partial(jax.jit, static_argnames=("interpret",))
def fused_sumsq(tree, *, interpret: bool = True):
    """Global Σx² over a pytree via the tiled Pallas reduction."""
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(K.sumsq(_to_tiles(l), interpret=interpret) for l in leaves)


@partial(jax.jit, static_argnames=("clip_norm", "interpret"))
def clip_accumulate(acc_tree, delta_tree, clip_norm: float,
                    *, interpret: bool = True):
    """acc ← acc + min(1, S/‖Δ‖)·Δ  (Algorithm 1's clip + round-sum), fused.
    Returns (new_acc_tree, pre-clip norm)."""
    ss = fused_sumsq(delta_tree, interpret=interpret)
    factor = clip_factor_ref(ss, clip_norm)

    def one(acc, delta):
        a2, d2 = _to_tiles(acc), _to_tiles(delta)
        out = K.clip_accumulate_2d(a2, d2, factor, interpret=interpret)
        return out.reshape(-1)[: acc.size].reshape(acc.shape)

    new_acc = jax.tree_util.tree_map(one, acc_tree, delta_tree)
    return new_acc, jnp.sqrt(ss)
