"""jit'd wrappers: pytree-level fused clip-and-accumulate.

``fused_sumsq(tree)`` / ``clip_accumulate(acc_tree, delta_tree, clip_norm)``
flatten each leaf, pad to the (ROWS·LANES) tile, and run the Pallas kernels.
``interpret=None`` auto-selects per backend (compiled Pallas on TPU, the
Pallas interpreter elsewhere — see `dp_clip.default_interpret`); pass
``interpret=True`` to force interpreter execution on any backend.

``clip_accumulate(..., scale=m)`` folds a 0/1 participation weight into the
clip factor so a masked cohort slot accumulates exactly ±0 — the streaming
engine path (`repro.fl.client.stream_block_sums`) uses this to keep padded
slots out of the round sum without a separate masking sweep.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.dp_clip import dp_clip as K
from repro.kernels.dp_clip.ref import clip_factor_ref


def _to_tiles(leaf):
    flat = leaf.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % K.TILE
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, K.LANES)


@partial(jax.jit, static_argnames=("interpret",))
def fused_sumsq(tree, *, interpret=None):
    """Global Σx² over a pytree via the tiled Pallas reduction."""
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(K.sumsq(_to_tiles(l), interpret=interpret) for l in leaves)


@partial(jax.jit, static_argnames=("clip_norm", "interpret"))
def clip_accumulate(acc_tree, delta_tree, clip_norm: float, scale=None,
                    *, interpret=None):
    """acc ← acc + scale·min(1, S/‖Δ‖)·Δ  (Algorithm 1's clip + round-sum),
    fused. ``scale`` (optional traced scalar, e.g. a 0/1 slot mask) is
    multiplied into the clip factor. Returns (new_acc_tree, pre-clip norm)."""
    ss = fused_sumsq(delta_tree, interpret=interpret)
    factor = clip_factor_ref(ss, clip_norm)
    if scale is not None:
        factor = factor * scale

    def one(acc, delta):
        a2, d2 = _to_tiles(acc), _to_tiles(delta)
        out = K.clip_accumulate_2d(a2, d2, factor, interpret=interpret)
        return out.reshape(-1)[: acc.size].reshape(acc.shape)

    new_acc = jax.tree_util.tree_map(one, acc_tree, delta_tree)
    return new_acc, jnp.sqrt(ss)
