"""Pallas TPU flash attention (causal / sliding-window / bidirectional).

Online-softmax tiling: the grid is (B, H, nQ, nK) with the KV dimension
innermost and sequential; running max `m`, normalizer `l`, and the output
accumulator live in VMEM scratch across KV steps. Block shapes are
(BLOCK_Q × head_dim) / (BLOCK_K × head_dim) with the MXU-aligned 128 lane
dimension; softmax statistics are carried broadcast across lanes.

The sliding-window mask is what lets the dense/MoE/VLM/audio architectures
run the ``long_500k`` decode shape sub-quadratically (DESIGN.md §4).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils.compat import tpu_compiler_params

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, causal: bool, window: int, scale: float, seq_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (BQ, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (BK, hd)
    v = v_ref[0, 0].astype(jnp.float32)            # (BK, hd)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (BQ, BK)

    q_idx = iq * BLOCK_Q + jax.lax.broadcasted_iota(jnp.int32,
                                                    scores.shape, 0)
    k_idx = ik * BLOCK_K + jax.lax.broadcasted_iota(jnp.int32,
                                                    scores.shape, 1)
    mask = k_idx < seq_k
    if causal:
        mask &= k_idx <= q_idx
    if window > 0:
        mask &= k_idx > q_idx - window
    scores = jnp.where(mask, scores, NEG_INF)

    m_prev = m_ref[...][:, :1]                      # (BQ, 1)
    m_cur = jnp.max(scores, axis=1, keepdims=True)  # (BQ, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)                 # (BQ, 1)
    p = jnp.exp(scores - m_new)                     # (BQ, BK)
    l_new = l_ref[...][:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == pl.num_programs(3) - 1)
    def _done():
        l = l_ref[...][:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         seq_k: int = None, interpret: bool = True):
    """q: (B, H, Sq, hd); k, v: (B, H, Sk, hd) (kv heads pre-broadcast).
    Sq/Sk padded to BLOCK multiples by the ops wrapper; ``seq_k`` is the
    TRUE (pre-padding) KV length — padded slots are masked."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    grid = (B, H, Sq // BLOCK_Q, Sk // BLOCK_K)
    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window,
        scale=1.0 / math.sqrt(hd), seq_k=seq_k if seq_k is not None else Sk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, BLOCK_Q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, BLOCK_K, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, BLOCK_K, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, BLOCK_Q, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BLOCK_Q, 128), jnp.float32),
            pltpu.VMEM((BLOCK_Q, 128), jnp.float32),
            pltpu.VMEM((BLOCK_Q, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
