"""jit'd wrapper: GQA (B,S,H,hd) layout → Pallas flash attention."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import (
    BLOCK_K, BLOCK_Q, flash_attention_bhsd)


def _pad_seq(x, block, axis):
    pad = (-x.shape[axis]) % block
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    interpret: bool = True):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd); GQA broadcast inside.
    Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qt = q.transpose(0, 2, 1, 3)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1)
    Sk_true = k.shape[1]
    qt, pq = _pad_seq(qt, BLOCK_Q, 2)
    kt, _ = _pad_seq(kt, BLOCK_K, 2)
    vt, _ = _pad_seq(vt, BLOCK_K, 2)
    # padded KV positions are masked by the TRUE seq_k inside the kernel
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               seq_k=Sk_true, interpret=interpret)
    if pq:
        out = out[:, :, :Sq, :]
    return out.transpose(0, 2, 1, 3)
