"""Pure-jnp oracle for flash attention (full-softmax, same masks)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,H,Sq,hd); k,v: (B,H,Sk,hd) → (B,H,Sq,hd)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    Sq, Sk = scores.shape[-2:]
    q_idx = jnp.arange(Sq)[:, None]
    k_idx = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_idx <= q_idx
    if window > 0:
        mask &= k_idx > q_idx - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)
