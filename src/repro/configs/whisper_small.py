"""whisper-small [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

Transformer backbone only; the mel-spectrogram + conv feature extractor is a
STUB — ``input_specs`` provides precomputed frame embeddings (B, 1500, d).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,       # decoder layers
    n_enc_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    n_audio_frames=1500,
    tie_embeddings=True,
    act="gelu",
    norm="layernorm",
    rope_theta=0.0,    # whisper uses learned/sinusoidal positions, not RoPE
    citation="arXiv:2212.04356 (Whisper)",
)
