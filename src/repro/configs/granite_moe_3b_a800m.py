"""granite-moe-3b-a800m [moe] — top-8 MoE [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,          # per-expert FFN width
    expert_d_ff=512,
    vocab=49155,
    n_experts=40,
    top_k=8,
    tie_embeddings=True,
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
