from repro.configs.base import (
    ClientConfig,
    DPConfig,
    InputShape,
    MeshConfig,
    ModelConfig,
    RunConfig,
    INPUT_SHAPES,
    SINGLE_POD,
    MULTI_POD,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
)
from repro.configs.registry import ALL_ARCHS, ASSIGNED_ARCHS, all_configs, get_config

__all__ = [
    "ClientConfig", "DPConfig", "InputShape", "MeshConfig", "ModelConfig",
    "RunConfig", "INPUT_SHAPES", "SINGLE_POD", "MULTI_POD", "TRAIN_4K",
    "PREFILL_32K", "DECODE_32K", "LONG_500K", "ALL_ARCHS", "ASSIGNED_ARCHS",
    "all_configs", "get_config",
]
