"""mamba2-370m [ssm] — SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

# d_inner = expand * d_model = 2048; SSD head_dim 64 → 32 SSD heads.
CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=16,        # unused by SSD path (attn-free); kept for layout parity
    n_kv_heads=16,
    d_ff=0,            # attn-free, no separate MLP: Mamba2 block is the mixer+channel mix
    vocab=50280,
    ssm_state=128,
    ssm_heads=32,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    tie_embeddings=True,
    norm="rmsnorm",
    citation="arXiv:2405.21060 (Mamba-2, SSD)",
)
