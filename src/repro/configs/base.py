"""Config system: dataclass configs for models, DP, FL, meshes, and input shapes.

Every assigned architecture gets a module in ``repro.configs`` exporting
``CONFIG``; the registry in :mod:`repro.configs.registry` resolves ``--arch``
strings to these. Configs are plain frozen dataclasses so they hash, compare,
and serialize trivially.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Architecture config covering all six assigned families.

    ``family`` selects the forward/init implementation:
      dense | moe | ssm | hybrid | encdec | vlm | lstm
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    # SSM / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0          # number of SSD heads (d_model // ssm_head_dim)
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    hybrid_attn_every: int = 6  # zamba2: shared attn block applied every N mamba blocks
    # enc-dec (whisper)
    n_enc_layers: int = 0
    n_audio_frames: int = 1500  # stub conv-frontend output length
    # vlm (chameleon)
    n_image_tokens: int = 1024  # VQ tokens per image (stub frontend)
    # attention behaviour
    rope_theta: float = 10_000.0
    attn_window: int = 0        # 0 = full causal; >0 = sliding window
    tie_embeddings: bool = True
    act: str = "swiglu"         # swiglu | gelu
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    # lstm: recurrence implementation — "fused" = time-fused sequence op
    # stepping the Pallas cifg_cell kernel, "seq" = the same sequence op
    # with the jnp cell, "ref" = plain scan + jax autodiff (the validated
    # reference), "auto" = fused on TPU / seq elsewhere. The hoisted input
    # GEMM applies to every path (see repro.models.lstm).
    cell_path: str = "auto"
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    citation: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        # keep GQA ratio representative: kv <= heads, divides heads
        while n_heads % n_kv:
            n_kv -= 1
        kw = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
        )
        if self.family == "moe":
            kw.update(n_experts=min(self.n_experts, 4), top_k=min(self.top_k, 2),
                      expert_d_ff=min(self.expert_d_ff, 256))
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=min(self.ssm_state, 16), ssm_heads=max(1, d_model * self.ssm_expand // 64),
                      hybrid_attn_every=2)
        if self.family == "encdec":
            kw.update(n_enc_layers=2, n_audio_frames=16)
        if self.family == "vlm":
            kw.update(n_image_tokens=8)
        return self.with_(**kw)


@dataclass(frozen=True)
class InputShape:
    """One of the four assigned global input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class DPConfig:
    """Algorithm 1 parameters (paper §II-A, Table 1)."""

    clip_norm: float = 0.8          # S
    noise_multiplier: float = 0.8   # z  (σ = z·S/(qN); paper: σ=3.2e-5, qN=20000 → z=0.8)
    clients_per_round: int = 20_000  # qN
    # round composition: "fixed" = exactly qN users WOR (Algorithm 1, the
    # deployed mechanism); "poisson" = each user i.i.d. Bernoulli(q) per
    # round [MRTZ17] — variable-size rounds, Δ̄ and σ still divided by the
    # *expected* round size qN. The accountant picks the matching bound
    # (WBK19 vs MTZ19) from this field.
    sampling: str = "fixed"         # "fixed" | "poisson"
    population: int = 4_000_000     # N (best estimate, paper §V-A)
    total_rounds: int = 2_000       # T
    server_opt: str = "momentum"    # sgd | momentum | adam  (Table 6)
    server_lr: float = 1.0          # η_s
    server_momentum: float = 0.99   # μ  (Nesterov)
    nesterov: bool = True
    adam_eps: float = 1e-7

    @property
    def noise_std(self) -> float:
        """σ on the *averaged* update (paper: 3.2e-5 at defaults)."""
        return self.noise_multiplier * self.clip_norm / self.clients_per_round


@dataclass(frozen=True)
class ClientConfig:
    """UserUpdate parameters (Algorithm 1, Table 1/7)."""

    local_epochs: int = 1       # E
    batch_size: int = 50        # B
    lr: float = 0.5             # η_c
    max_examples_per_user: int = 200  # paper §I: per-user data caps


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: InputShape
    mesh: MeshConfig = SINGLE_POD
    dp: DPConfig = field(default_factory=DPConfig)
    client: ClientConfig = field(default_factory=ClientConfig)
    remat: bool = True
    microbatch_clients: int = 0  # 0 → one scan step per data-parallel row
