"""``--arch`` string → ModelConfig resolution."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig

_ARCH_MODULES = {
    "mamba2-370m": "mamba2_370m",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "granite-3-2b": "granite_3_2b",
    "chameleon-34b": "chameleon_34b",
    "stablelm-12b": "stablelm_12b",
    "zamba2-2.7b": "zamba2_2_7b",
    "whisper-small": "whisper_small",
    "phi3-medium-14b": "phi3_medium_14b",
    "gboard-cifg-lstm": "gboard_lstm",
}

ASSIGNED_ARCHS = [k for k in _ARCH_MODULES if k != "gboard-cifg-lstm"]
ALL_ARCHS = list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ALL_ARCHS}
