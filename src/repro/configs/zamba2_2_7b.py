"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig

# 54 mamba2 layers; a single *shared* GQA attention block is interleaved every
# `hybrid_attn_every` mamba blocks (weights shared across applications, distinct
# KV caches per application site), per the Zamba2 design.
CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,        # shared attn block's MLP width
    vocab=32000,
    ssm_state=64,
    ssm_heads=80,      # d_inner=5120, head_dim 64
    ssm_head_dim=64,
    ssm_expand=2,
    hybrid_attn_every=6,
    tie_embeddings=True,
    citation="arXiv:2411.15242 (Zamba2)",
)
