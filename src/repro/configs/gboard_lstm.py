"""The paper's own production NWP model: 1-layer CIFG-LSTM, tied embeddings,
~1.3M parameters, 10k word vocabulary [this paper §III-A; SSB14].
"""
from repro.configs.base import ModelConfig

# Embedding dim 96 (tied in/out projection), CIFG hidden 256:
#   embed 10k×96 = 0.96M; CIFG gates 3·(96+256+1)·256 ≈ 0.27M; proj 256→96 ≈ 25k
#   total ≈ 1.26M ≈ the paper's 1.3M.
CONFIG = ModelConfig(
    name="gboard-cifg-lstm",
    family="lstm",
    n_layers=1,
    d_model=96,        # embedding dim (tied input embedding / output projection)
    n_heads=1,
    n_kv_heads=1,
    d_ff=256,          # CIFG-LSTM hidden size
    vocab=10_000,
    tie_embeddings=True,
    citation="this paper §III-A; arXiv:1402.1128 (CIFG-LSTM)",
)
