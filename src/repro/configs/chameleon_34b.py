"""chameleon-34b [vlm] — early-fusion, VQ image tokens [arXiv:2405.09818].

Transformer backbone only; the VQ-VAE image tokenizer frontend is a STUB —
``input_specs`` provides precomputed patch-token embeddings of the right shape.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65536,       # unified text + VQ image-token vocabulary (early fusion)
    n_image_tokens=1024,
    tie_embeddings=False,
    act="swiglu",
    citation="arXiv:2405.09818 (Chameleon)",
)
