"""olmoe-1b-7b [moe] — 64 experts top-8 [arXiv:2409.02060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,     # GQA kv=16 (MHA)
    d_ff=1024,         # per-expert FFN width
    expert_d_ff=1024,
    vocab=50304,
    n_experts=64,
    top_k=8,
    tie_embeddings=False,
    citation="arXiv:2409.02060 (OLMoE)",
)
