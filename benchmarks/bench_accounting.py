"""Paper Table 5: hypothetical (ε, δ)-DP upper bounds for the production run
(T=2000, qN=20000, z=0.8, δ=N^-1.1) across population sizes, under both the
paper's fixed-size-w/o-replacement accountant (WBK19) and the Poisson
accountant (MTZ19)."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core.accountant import table5_epsilon

PAPER_TABLE5 = {2_000_000: 9.86, 3_000_000: 6.73, 4_000_000: 5.36,
                5_000_000: 4.54, 10_000_000: 3.27}


def run():
    rows = []
    for N, eps_paper in sorted(PAPER_TABLE5.items()):
        (eps_wor, us) = timed(table5_epsilon, N, sampling="wor")
        eps_poisson, _ = timed(table5_epsilon, N, sampling="poisson")
        rows.append((N, eps_poisson, eps_wor, eps_paper))
        emit(f"table5/N={N//10**6}M", us,
             f"eps_wor={eps_wor:.2f};eps_poisson={eps_poisson:.2f};"
             f"paper={eps_paper:.2f};rel_err_wor={abs(eps_wor-eps_paper)/eps_paper:.3f}")
    return rows


if __name__ == "__main__":
    run()
