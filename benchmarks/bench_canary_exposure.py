"""Paper Table 3: expected number of times each (n_u, n_e) canary is seen in
training. Analytic (the paper's 1150-participations-per-device estimate) and
measured from the Pace-Steering population simulation."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.fl.population import PopulationSim
from repro.fl.sampling import sample_round

GRID = [(1, 1), (1, 14), (1, 200), (4, 1), (4, 14), (4, 200),
        (16, 1), (16, 14), (16, 200)]
PAPER = {(1, 1): 1_150, (1, 14): 16_100, (1, 200): 230_000,
         (4, 1): 4_600, (4, 14): 64_400, (4, 200): 920_000,
         (16, 1): 18_400, (16, 14): 257_600, (16, 200): 3_680_000}


def simulate_participation(n_users=4_000, n_synth=189, rounds=400,
                           clients_per_round=200, availability=0.02):
    """Scaled-down fleet: measure synthetic-device participations/round."""
    synth_ids = list(range(n_users - n_synth, n_users))
    pop = PopulationSim(n_users, availability=availability,
                        pace_cooldown=50, synthetic_ids=synth_ids, seed=0)
    rng = np.random.default_rng(0)
    part = np.zeros(n_users)
    for r in range(rounds):
        ids = sample_round(pop, rng, r, clients_per_round)
        part[ids] += 1
    return part[synth_ids].mean() / rounds, part[:n_users - n_synth].mean() / rounds


def run():
    (synth_rate, real_rate), us = timed(simulate_participation)
    # paper: each synthetic device participates ≈1150 times in T=2000 rounds
    per_device = synth_rate * 2000
    emit("table3/participation_sim", us,
         f"synth_per_2000_rounds={per_device:.0f};paper=1150;"
         f"synth_vs_real_ratio={synth_rate/max(real_rate,1e-9):.1f}")
    for (n_u, n_e) in GRID:
        expected = n_u * n_e * per_device
        emit(f"table3/nu={n_u}_ne={n_e}", 0.0,
             f"expected_seen={expected:.0f};paper={PAPER[(n_u, n_e)]};"
             f"scaled_ratio={expected / (n_u * n_e * 1150):.2f}")
    return per_device


if __name__ == "__main__":
    run()
