"""Paper Table 3: expected number of times each (n_u, n_e) canary is seen in
training, engine-backed.

The participation dynamics (availability gating + Pace Steering with
always-available synthetic devices) now run *on device* inside the compiled
simulation engine: a full DP-FedAvg sweep over a population with the paper's
27 injected canaries (189 synthetic devices), with per-device participation
counts read back from `EngineState.participation`. The original pure-numpy
`PopulationSim` loop is kept as the cross-check — both estimates of the
synthetic-vs-real participation gap are emitted, next to the paper's
analytic 1150-participations-per-device figure.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, timed
from repro.configs import ClientConfig, DPConfig, get_config
from repro.core.secret_sharer import make_canaries
from repro.data.corpus import BigramCorpus
from repro.data.federated import FederatedDataset
from repro.fl.population import PopulationSim, participation_rates
from repro.fl.round import FederatedTrainer
from repro.fl.sampling import sample_round

GRID = [(1, 1), (1, 14), (1, 200), (4, 1), (4, 14), (4, 200),
        (16, 1), (16, 14), (16, 200)]
PAPER = {(1, 1): 1_150, (1, 14): 16_100, (1, 200): 230_000,
         (4, 1): 4_600, (4, 14): 64_400, (4, 200): 920_000,
         (16, 1): 18_400, (16, 14): 257_600, (16, 200): 3_680_000}

VOCAB = 64  # participation dynamics don't depend on the model; keep it tiny


def simulate_participation_host(n_real=2_000, n_synth=189, rounds=400,
                                clients_per_round=200, availability=0.02):
    """Numpy reference: measure synthetic-device participations/round.
    Same fleet shape as the engine path: n_real real devices + n_synth
    always-available synthetic ones appended."""
    n_users = n_real + n_synth
    synth_ids = list(range(n_real, n_users))
    pop = PopulationSim(n_users, availability=availability,
                        pace_cooldown=50, synthetic_ids=synth_ids, seed=0)
    rng = np.random.default_rng(0)
    part = np.zeros(n_users)
    for r in range(rounds):
        ids = sample_round(pop, rng, r, clients_per_round)
        part[ids] += 1
    synth = np.zeros(n_users, bool)
    synth[synth_ids] = True
    return participation_rates(part, synth, rounds)


def simulate_participation_engine(n_users=2_000, rounds=400,
                                  clients_per_round=200, availability=0.02):
    """Engine path: the same dynamics on device, measured from a real
    DP-FedAvg run over the canary-injected population. Returns
    ((synth_rate, real_rate), rounds_per_sec)."""
    cfg = get_config("gboard-cifg-lstm").with_(vocab=VOCAB, d_model=8,
                                               d_ff=16)
    from repro.models import build
    model = build(cfg)
    corpus = BigramCorpus(vocab_size=VOCAB, seed=0)
    ds = FederatedDataset(corpus, n_users=n_users, seq_len=16,
                          sentences_per_user=4)
    ds.inject_canaries(make_canaries(jax.random.PRNGKey(42), vocab=VOCAB,
                                     grid=GRID, per_config=3))
    dp = DPConfig(clients_per_round=clients_per_round, noise_multiplier=0.3,
                  clip_norm=0.8, server_opt="momentum", server_lr=0.5,
                  server_momentum=0.9)
    cl = ClientConfig(local_epochs=1, batch_size=4, lr=0.3)
    pop = PopulationSim(len(ds.users), availability=availability,
                        pace_cooldown=50,
                        synthetic_ids=[u.user_id for u in ds.users
                                       if u.is_synthetic], seed=0)
    tr = FederatedTrainer(model, ds, dp, cl, pop=pop, n_local_batches=1,
                          seed=0, backend="engine", rounds_per_call=50)
    tr.train(10)                                   # compile + warmup
    t0 = time.perf_counter()
    tr.train(rounds - 10)
    rps = (rounds - 10) / (time.perf_counter() - t0)
    synth = np.asarray([u.is_synthetic for u in ds.users])
    return participation_rates(tr.participation, synth, rounds), rps


def run(rounds: int = 400):
    (h_synth, h_real), host_us = timed(simulate_participation_host,
                                       rounds=rounds)
    ((e_synth, e_real), eng_rps), eng_us = timed(
        simulate_participation_engine, rounds=rounds)
    # paper: each synthetic device participates ≈1150 times in T=2000 rounds
    per_device = e_synth * 2000
    emit("table3/participation_engine", eng_us,
         f"synth_per_2000_rounds={per_device:.0f};paper=1150;"
         f"synth_vs_real_ratio={e_synth / max(e_real, 1e-9):.1f};"
         f"rounds_per_sec={eng_rps:.2f}")
    emit("table3/participation_host_ref", host_us,
         f"synth_per_2000_rounds={h_synth * 2000:.0f};"
         f"synth_vs_real_ratio={h_synth / max(h_real, 1e-9):.1f};"
         f"engine_vs_host_ratio={e_synth / max(h_synth, 1e-9):.2f}")
    for (n_u, n_e) in GRID:
        expected = n_u * n_e * per_device
        emit(f"table3/nu={n_u}_ne={n_e}", 0.0,
             f"expected_seen={expected:.0f};paper={PAPER[(n_u, n_e)]};"
             f"scaled_ratio={expected / (n_u * n_e * 1150):.2f}")
    return per_device


if __name__ == "__main__":
    run()
