"""Kernel micro-benchmarks: Pallas (interpret=True on CPU — correctness
surrogate; TPU is the compile target) vs the pure-jnp reference path, plus
the XLA fallback used by the models."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.kernels.cifg_cell import cifg_cell_ref, cifg_step
from repro.kernels.dp_clip.ops import clip_accumulate
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref

KEY = jax.random.PRNGKey(0)


def _cifg_cell_bench():
    """Paper-scale CIFG recurrent step (B=50, d=96, h=256): fused Pallas
    cell (interpret on CPU) vs the post-split jnp reference vs the pre-split
    XLA cell (concat + fused w_gates — the PR-4 compute graph)."""
    B, d, h = 50, 96, 256
    ks = jax.random.split(KEY, 5)
    zx = jax.random.normal(ks[0], (B, 3 * h))
    hs = jax.random.normal(ks[1], (B, h)) * 0.3
    cs = jax.random.normal(ks[2], (B, h)) * 0.3
    wh = jax.random.normal(ks[3], (h, 3 * h)) * 0.1
    x = jax.random.normal(ks[4], (B, d))
    wg = jnp.concatenate(  # pre-split layout: (d+h, 3h)
        [jax.random.normal(ks[0], (d, 3 * h)) * 0.1, wh], axis=0)
    b = jnp.zeros((3 * h,))

    def presplit_cell(x, hs, cs):
        z = jnp.concatenate([x, hs], axis=-1) @ wg + b
        f = jax.nn.sigmoid(z[:, :h] + 1.0)
        o = jax.nn.sigmoid(z[:, h:2 * h])
        g = jnp.tanh(z[:, 2 * h:])
        c_new = f * cs + (1.0 - f) * g
        return o * jnp.tanh(c_new), c_new

    fused = jax.jit(lambda zx, hs, cs: cifg_step(zx, hs, cs, wh))
    ref = jax.jit(lambda zx, hs, cs: cifg_cell_ref(zx, hs, cs, wh))
    pre = jax.jit(presplit_cell)
    _, us_fused = timed(lambda: jax.block_until_ready(fused(zx, hs, cs)),
                        repeats=20)
    _, us_ref = timed(lambda: jax.block_until_ready(ref(zx, hs, cs)),
                      repeats=20)
    _, us_pre = timed(lambda: jax.block_until_ready(pre(x, hs, cs)),
                      repeats=20)
    emit("kernel/cifg_cell_step", us_fused,
         f"jnp_ref_us={us_ref:.0f};presplit_xla_us={us_pre:.0f};"
         "note=interpret_mode_cpu;presplit_includes_input_proj")


def run():
    _cifg_cell_bench()
    # dp_clip on a ~1.3M-param tree (the paper's model size)
    tree = {"a": jax.random.normal(KEY, (10_000, 96)),
            "b": jax.random.normal(jax.random.fold_in(KEY, 1), (96, 3000))}
    acc = jax.tree_util.tree_map(jnp.zeros_like, tree)
    out, us = timed(lambda: jax.block_until_ready(
        clip_accumulate(acc, tree, 0.8)), repeats=3)
    emit("kernel/dp_clip_1.3M", us, "interpret=True;vs_ref=validated_in_tests")

    # flash attention 1×1024×8×64
    q = jax.random.normal(KEY, (1, 1024, 8, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 1024, 8, 64),
                          jnp.bfloat16)
    _, us_pallas = timed(lambda: jax.block_until_ready(
        flash_attention(q, k, k)), repeats=2)
    ref = jax.jit(lambda q, k, v: attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3)))
    _, us_ref = timed(lambda: jax.block_until_ready(ref(q, k, k)), repeats=2)
    emit("kernel/flash_attention_1k", us_pallas,
         f"xla_ref_us={us_ref:.0f};note=interpret_mode_cpu")

    # ssd scan 1×1024×8 heads
    x = jax.random.normal(KEY, (1, 1024, 8, 64))
    dt = jax.nn.softplus(jax.random.normal(KEY, (1, 1024, 8))) * 0.1
    Bm = jax.random.normal(KEY, (1, 1024, 64))
    A = -jnp.exp(jax.random.normal(KEY, (8,)))
    _, us_k = timed(lambda: jax.block_until_ready(
        ssd_scan(x, dt, Bm, Bm, A)), repeats=2)
    refj = jax.jit(ssd_scan_ref)
    _, us_r = timed(lambda: jax.block_until_ready(
        refj(x, dt, Bm, Bm, A)), repeats=2)
    emit("kernel/ssd_scan_1k", us_k,
         f"sequential_ref_us={us_r:.0f};note=interpret_mode_cpu")


if __name__ == "__main__":
    run()
