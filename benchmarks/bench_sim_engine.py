"""Simulation-engine throughput: compiled lax.scan engine vs host loop.

Reports rounds/sec for the Python-loop `FederatedTrainer` (numpy sampling +
host tensor stacking + one jit entry per round) against the compiled
`SimEngine` (K rounds per jit call, device-resident population/data) at
cohort sizes {50, 200, 1000} — the regime of the paper's secret-sharer
sweeps and Table 6/7/8 ablations, where thousands of simulated rounds make
driver throughput the binding constraint.

Two host baselines are reported:

* ``host`` — the driver as the repo's sweeps actually ran it: the
  availability-gated check-in pool fluctuates below qN, so the stacked
  client tensor changes shape and the round function *re-traces jit almost
  every round*. This is the status quo the engine replaces (its fixed-size
  on-device cohort makes every round the same program).
* ``host_fixed_cohort`` — ample availability so the cohort is always
  exactly qN: one compile, steady state; isolates the engine's win from
  per-round dispatch/stacking/donation alone.

The sharded sweep additionally reports rounds/sec for every shard count in
``--shards`` that the visible device count supports (engine backend,
``num_shards=S``): on CPU run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to exercise the
whole {1, 2, 4, 8} grid.

``--chunk-sweep`` benchmarks the *streaming* cohort accumulation
(`SimEngine(cohort_chunk=…)`) at cohorts {200, 1000, 5000}: for each chunk
size it emits steady-state rounds/sec (compile time split out into
``compile_s``, two warm-up calls before the timer), the compiled round
program's peak live-buffer bytes (``jax.jit(...).lower().compile()
.memory_analysis().temp_size_in_bytes``), and the resolved chunk
(``auto=1`` marks `reduction.auto_chunk`'s own choice) — the
memory/throughput trajectory the streaming path exists for. ``chunk=0`` is
the materializing baseline; when its estimated peak exceeds
``BENCH_MEM_RUN_LIMIT`` bytes (default 2 GB) the record keeps the memory
number but skips the timed run rather than swapping the box.

``--pod-sweep`` benchmarks the 2-D ``(pod, data)`` cohort layout: rounds/
sec per (pods, shards) topology in the bit-parity family — the trajectories
are bit-identical by construction (see tests/test_engine_pods.py), so the
records isolate the layout's collective cost. ``BENCH_ci.json`` carries a
``sim_engine/pods=2`` point from the dry run so cross-pod throughput is
tracked per PR.

``--population-sweep`` benchmarks the *streamed population backend*
(`SimEngine(population_backend="streamed")`, PR 7) across population sizes
10³ → 10⁶ (10⁷ sharded-sampler-only): the corpus stays host-resident (a
`ReplicatedPopulationStore` view at large N) and only two ping-ponged
cohort buffers live on device, so rounds/sec should stay flat in N while
``device_corpus_bytes`` stays constant — vs the device-resident reference
whose corpus residency grows linearly. Each streamed size runs under both
cohort samplers (``sampler=global`` / ``sampler=sharded`` in the record
tag) with the per-round time split into ``sample_s`` vs ``compute_s``, so
the sharded sampler's O(N)-selection win is attributable; the global
sampler's O(N) argsort is what bends the global curve down past 10⁵. The
dry run emits device + streamed×{global, sharded} records into
``BENCH_ci.json`` (asserted by `tools/ci.sh`); the nightly full sweep lands
in ``BENCH_population.json``.

``--fault-sweep`` benchmarks the *production fault protocol*
(`SimEngine(fault_config=…)`, PR 9) across dropout rates 0 → 0.5 with
stragglers and corrupt reports held fixed: rounds/sec under over-selection
plus ``committed_frac`` / ``wasted_work_frac`` — the throughput and wasted
client computation the deployed report-goal protocol trades for round
reliability. Dry run emits one record into ``BENCH_ci.json`` (asserted by
`tools/ci.sh`); the nightly full sweep lands in ``BENCH_faults.json``.

``--client-step`` (also emitted after every full/dry run) is the
local-SGD *numerator* microbench: µs per jit'd client step
(``value_and_grad`` of the model loss on one client batch) per
``cell_path`` — the unit the PR-5 time-fused CIFG client step optimizes,
tracked per PR via the CI smoke.

    PYTHONPATH=src python benchmarks/bench_sim_engine.py [--dry-run]

``--dry-run`` shrinks cohorts/rounds to a seconds-long CI smoke (including
one streaming-vs-materializing chunk record and the client-step records).
"""
from __future__ import annotations

import argparse
import math
import os
import time

import jax

from benchmarks.common import emit
from repro.configs import ClientConfig, DPConfig, get_config
from repro.data.corpus import BigramCorpus
from repro.data.federated import FederatedDataset
from repro.fl.engine import SimEngine
from repro.fl.population import PopulationSim
from repro.fl.reduction import CANON_BLOCKS, canon_pad
from repro.fl.round import FederatedTrainer
from repro.models import build

VOCAB = 300  # small NWP config: round *driver* overhead (stacking,
D_MODEL = 24  # retracing, dispatch), not matmuls, should dominate —
D_FF = 48     # that's what this bench isolates

# --chunk-sweep: don't execute (only compile) configurations whose peak
# live buffers exceed this — the materializing baseline at cohort 5000
# wants ~8 GB of temp on CPU
MEM_RUN_LIMIT = int(os.environ.get("BENCH_MEM_RUN_LIMIT", 2 * 10 ** 9))


def _setup(n_users: int):
    cfg = get_config("gboard-cifg-lstm").with_(vocab=VOCAB, d_model=D_MODEL,
                                               d_ff=D_FF)
    model = build(cfg)
    corpus = BigramCorpus(vocab_size=VOCAB, seed=0)
    ds = FederatedDataset(corpus, n_users=n_users, seq_len=16,
                          sentences_per_user=20)
    return cfg, model, ds


def _rounds_per_sec(tr: FederatedTrainer, warmup: int, rounds: int) -> float:
    tr.train(warmup)                      # compile + steady-state
    t0 = time.perf_counter()
    tr.train(rounds)
    return rounds / (time.perf_counter() - t0)


def _chunk_record(model, data, dp, cl, *, cohort, chunk, rounds, k,
                  mem_baseline=None):
    """One streaming-accumulation record: build the engine at this
    ``cohort_chunk``, read the compiled k-round program's peak live-buffer
    bytes, then (if it fits under MEM_RUN_LIMIT) time actual rounds through
    the same AOT executable — one compile per record.

    Compile time and steady state are reported *separately* (``compile_s``
    vs ``rounds_per_sec``; two warm-up calls run before the timer starts):
    the PR-4 sweep timed a single post-warmup window per record, which let
    first-call effects (lazy allocation, cache-cold sweeps of the chunk's
    working set) masquerade as steady-state throughput and made the
    cohort-5000 trajectory look non-monotone in the chunk size. The record
    also carries ``resolved_chunk`` and ``auto=1`` when ``chunk=None`` so
    regressions of `reduction.auto_chunk`'s choice are visible in the
    archive. Returns (peak_bytes, rounds_per_sec — NaN when the run was
    skipped)."""
    eng = SimEngine(model, data, dp, cl, n_local_batches=2, availability=0.5,
                    rounds_per_call=k, cohort_chunk=chunk)
    state = eng.init_state(model.init(jax.random.PRNGKey(1)), seed=0)
    t0 = time.perf_counter()
    compiled = eng._run_k(k).lower(state).compile()
    compile_s = time.perf_counter() - t0
    peak = compiled.memory_analysis().temp_size_in_bytes
    rps = float("nan")
    if peak <= MEM_RUN_LIMIT:
        for _ in range(2):                        # warm-up calls
            state, _ = compiled(state)
        n_calls = max(1, rounds // k)
        t0 = time.perf_counter()
        for _ in range(n_calls):
            state, _ = compiled(state)
        jax.block_until_ready(state.params)
        rps = n_calls * k / (time.perf_counter() - t0)
    derived = (f"rounds_per_sec={rps:.3f};compile_s={compile_s:.1f};"
               f"peak_bytes={peak};resolved_chunk={eng.cohort_chunk}")
    if chunk is None:
        derived += ";auto=1"
    if mem_baseline and peak:
        derived += f";mem_reduction_vs_materialize={mem_baseline / peak:.1f}x"
    if math.isnan(rps):
        # memory-only record: 0.0 = "unmeasured" (a negative or NaN value
        # would poison downstream min/mean aggregation of the trajectory)
        derived += f";run_skipped=peak>{MEM_RUN_LIMIT}B"
    emit(f"sim_engine/chunked/cohort={cohort}/chunk="
         f"{'materialize' if chunk == 0 else eng.cohort_chunk}",
         0.0 if math.isnan(rps) else 1e6 / rps, derived)
    return peak, rps


def client_step_bench(dry_run: bool = False):
    """Client-step microbench: µs per client local-SGD step (jit'd
    ``value_and_grad`` of the model loss on one client batch) at the bench
    model config — the engine hot path's unit of work, tracked per PR in
    ``BENCH_ci.json`` so regressions on the local-SGD numerator are visible
    without waiting for the full cohort sweep. Emits one record per
    ``cell_path`` (the resolved default plus the pre-PR-5-style reference
    scan)."""
    import jax.numpy as jnp

    from repro.models.lstm import resolve_cell_path

    B, S = ClientConfig().batch_size, 16
    repeats = 5 if dry_run else 30
    cfg0, _, _ = _setup(50)
    for path in ("auto", "ref"):
        cfg = cfg0.with_(cell_path=path)
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                                    cfg.vocab)
        batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:],
                 "mask": jnp.ones((B, S), jnp.float32)}
        step = jax.jit(jax.value_and_grad(model.loss_fn))
        out = step(params, batch)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = step(params, batch)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / repeats * 1e6
        emit(f"client_step/local_sgd/cell={path}", us,
             f"resolved={resolve_cell_path(cfg)};B={B};S={S};"
             f"d={cfg.d_model};h={cfg.d_ff}")


def chunk_sweep(dry_run: bool = False):
    """--chunk-sweep: rounds/sec + peak live-buffer bytes across
    ``cohort_chunk`` at cohorts {200, 1000, 5000} (the paper's production
    regime needs the 5k leg — the materializing path can't run it on a
    laptop-class box at all, which is the point)."""
    cohorts = [8] if dry_run else [200, 1000, 5000]
    for cohort in cohorts:
        n_users = max(2 * cohort, 50)
        cfg, model, ds = _setup(n_users)
        data = ds.to_device_arrays()
        dp = DPConfig(clients_per_round=cohort, noise_multiplier=0.3,
                      clip_norm=0.8, server_opt="momentum", server_lr=0.5,
                      server_momentum=0.9)
        cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
        rounds = 2 if dry_run else max(2, 8000 // cohort)
        k = 2 if dry_run else min(4, rounds)
        blk = canon_pad(cohort) // CANON_BLOCKS   # canonical block size
        # materializing baseline first so streaming records carry the ratio
        mem0, _ = _chunk_record(model, data, dp, cl, cohort=cohort, chunk=0,
                                rounds=rounds, k=k)
        chunks = [None] if dry_run else \
            [c for c in (5, None, 125) if c is None or blk % c == 0]
        for chunk in chunks:
            _chunk_record(model, data, dp, cl, cohort=cohort, chunk=chunk,
                          rounds=rounds, k=k, mem_baseline=mem0)


def pod_sweep(dry_run: bool = False):
    """--pod-sweep: rounds/sec per (pods, shards) topology of the 2-D
    ``(pod, data)`` cohort mesh (engine backend, ``num_pods × num_shards``
    devices). Every topology in the sweep is in the bit-parity family
    (total dividing CANON_BLOCKS), so the records measure pure layout cost:
    the trajectories are bit-identical, only the collective pattern (intra-
    pod gather + pod-partial exchange vs one flat gather) changes. On CPU
    run under ``XLA_FLAGS=--xla_force_host_platform_device_count=16`` to
    cover the whole grid."""
    topologies = ((1, 1), (2, 1), (2, 2), (2, 4), (4, 2))
    n_dev = len(jax.devices())
    fit = [(p, s) for p, s in topologies if p * s <= n_dev]
    skipped = [t for t in topologies if t not in fit]
    if skipped:
        print(f"bench_sim_engine: skipping pod topologies {skipped} "
              f"(only {n_dev} devices visible; set XLA_FLAGS="
              "--xla_force_host_platform_device_count=16)")
    cohorts = [8] if dry_run else [200, 1000]
    rounds = 4 if dry_run else 40
    results = {}
    for cohort in cohorts:
        n_users = max(6 * cohort, 50)
        cfg, model, ds = _setup(n_users)
        dp = DPConfig(clients_per_round=cohort, noise_multiplier=0.3,
                      clip_norm=0.8, server_opt="momentum", server_lr=0.5,
                      server_momentum=0.9)
        cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
        ref_rps = None
        for pods, shards in fit:
            tr = FederatedTrainer(model, ds, dp, cl,
                                  pop=PopulationSim(n_users,
                                                    availability=0.5,
                                                    seed=0),
                                  n_local_batches=2, seed=0,
                                  backend="engine", num_pods=pods,
                                  num_shards=shards,
                                  rounds_per_call=min(20, rounds))
            rps = _rounds_per_sec(tr, min(20, rounds), rounds)
            if ref_rps is None:
                ref_rps = rps                 # (1, 1) leads the sweep
            emit(f"sim_engine/pods/cohort={cohort}/pods={pods}/"
                 f"shards={shards}", 1e6 / rps,
                 f"rounds_per_sec={rps:.3f};"
                 f"vs_unsharded={rps / ref_rps:.2f}x;"
                 f"total_shards={pods * shards}")
            results[(cohort, pods, shards)] = rps
    return results


def _population_record(model, data, dp, cl, *, backend, n_users, rounds,
                       warmup, rpc, sampler="global", ref_rps=None):
    """One population-scale record: rounds/sec through `SimEngine.run` at
    this ``population_backend`` × ``sampler``, plus the memory accounting
    that is the point of the streamed backend — ``device_corpus_bytes``
    (what the backend keeps resident on device for the population payload:
    the whole padded corpus, or two ping-ponged cohort buffers independent
    of N) and ``host_corpus_bytes`` (the virtual population payload).

    The per-round time is split into ``sample_s`` (the cohort-selection +
    population-vector chain, timed alone through the same jitted sampler
    body via `SimEngine.run_sampler`) and ``compute_s`` (the remainder:
    staging + local SGD + reduction + server step) so the sampler's O(N)
    share — the thing ``sampler="sharded"`` attacks — is attributable per
    record."""
    eng = SimEngine(model, data, dp, cl, n_local_batches=2,
                    availability=0.5, rounds_per_call=rpc,
                    sampler=sampler, population_backend=backend)
    state = eng.init_state(model.init(jax.random.PRNGKey(1)), seed=0)
    # warmup/rounds are multiples of rpc so the device backend's k-round
    # scan compiles exactly once, outside the timed window
    state, _ = eng.run(state, warmup)
    t0 = time.perf_counter()
    state, _ = eng.run(state, rounds)
    jax.block_until_ready(state.params)
    rps = rounds / (time.perf_counter() - t0)
    # sampler-only attribution: same chain, fresh state, no staging/compute
    sstate = eng.init_state(model.init(jax.random.PRNGKey(1)), seed=0)
    sstate = eng.run_sampler(sstate, warmup)
    t0 = time.perf_counter()
    eng.run_sampler(sstate, rounds)
    sample_s = (time.perf_counter() - t0) / rounds
    compute_s = max(1.0 / rps - sample_s, 0.0)
    row_bytes = eng.emax * eng.row_len * 4
    dev = (n_users * row_bytes if backend == "device"
           else 2 * eng.padded * row_bytes)
    derived = (f"rounds_per_sec={rps:.3f};"
               f"sample_s={sample_s:.4f};compute_s={compute_s:.4f};"
               f"device_corpus_bytes={dev};"
               f"host_corpus_bytes={n_users * row_bytes};"
               f"cohort_padded={eng.padded}")
    if ref_rps is not None:
        derived += f";vs_device_base={rps / ref_rps:.2f}x"
    emit(f"sim_engine/population/n_users={n_users}/backend={backend}/"
         f"sampler={eng.sampler}", 1e6 / rps, derived)
    return rps


def population_sweep(dry_run: bool = False):
    """--population-sweep: rounds/sec across population sizes 10³ → 10⁶ for
    the streamed (host-resident corpus, double-buffered cohort prefetch)
    backend, with the device-resident backend as the N=10³ reference — the
    headline claim is rounds/sec flat in N with per-round device residency
    independent of N. Large N uses `ReplicatedPopulationStore` (an O(1)-host-
    memory tiled view over a 10³-user base), so the sweep measures sampler +
    gather + transfer + compute at true fleet id-space size without a
    multi-GB corpus build."""
    from repro.data.population_store import (InMemoryPopulationStore,
                                             ReplicatedPopulationStore)
    base_users = 200 if dry_run else 1000
    cohort = 8 if dry_run else 200
    rpc = 2 if dry_run else 10
    rounds = 4 if dry_run else 30
    warmup = 2 if dry_run else 10
    cfg, model, ds = _setup(base_users)
    base = InMemoryPopulationStore.from_dataset(ds)
    dp = DPConfig(clients_per_round=cohort, noise_multiplier=0.3,
                  clip_norm=0.8, server_opt="momentum", server_lr=0.5,
                  server_momentum=0.9)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    # device-resident reference at base N only (it materializes the corpus
    # on device, which is exactly the wall this sweep demonstrates)
    ref = _population_record(model, base.device_arrays(), dp, cl,
                             backend="device", n_users=base_users,
                             rounds=rounds, warmup=warmup, rpc=rpc)
    sizes = [base_users] if dry_run else [1000, 10_000, 100_000, 1_000_000]
    results = {}
    for n in sizes:
        store = (base if n == base_users
                 else ReplicatedPopulationStore(base, n))
        for sampler in ("global", "sharded"):
            results[(n, sampler)] = _population_record(
                model, store, dp, cl, backend="streamed", n_users=n,
                rounds=rounds, warmup=warmup, rpc=rpc, sampler=sampler,
                ref_rps=ref)
    # the fleet-scale point: N=10⁷ is sharded-sampler-only — the global
    # sampler's O(N) argsort makes it minutes per timed window out there,
    # which is the regime boundary this record documents
    if not dry_run:
        n = 10_000_000
        results[(n, "sharded")] = _population_record(
            model, ReplicatedPopulationStore(base, n), dp, cl,
            backend="streamed", n_users=n, rounds=max(rounds // 2, 10),
            warmup=max(warmup // 2, 4), rpc=rpc, sampler="sharded",
            ref_rps=ref)
    return results


def fault_sweep(dry_run: bool = False):
    """--fault-sweep: rounds/sec + protocol overhead vs dropout rate under
    the production fault model (`fl.faults.FaultConfig`, PR 9). Each record
    runs the over-selection/report-goal protocol (stragglers + corrupt
    reports held fixed, dropout swept) and reports ``committed_frac`` (the
    fraction of rounds that reached the report goal and released an update)
    and ``wasted_work_frac`` (selected client computations that never made
    it into a committed release — the price of dropout + over-selection the
    deployed system actually pays). The dry run emits the single
    ``sim_engine/faults/...`` record asserted by `tools/ci.sh`; the nightly
    full sweep lands in ``BENCH_faults.json``."""
    from repro.fl.faults import FaultConfig
    cohort = 8 if dry_run else 200
    rounds = 4 if dry_run else 60
    warmup = 2 if dry_run else 10
    rpc = 2 if dry_run else 10
    dropouts = [0.3] if dry_run else [0.0, 0.1, 0.3, 0.5]
    n_users = max(10 * cohort, 80)
    cfg, model, ds = _setup(n_users)
    data = ds.to_device_arrays()
    dp = DPConfig(clients_per_round=cohort, noise_multiplier=0.3,
                  clip_norm=0.8, server_opt="momentum", server_lr=0.5,
                  server_momentum=0.9)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    results = {}
    for p in dropouts:
        fc = FaultConfig(seed=0, dropout_prob=p, straggler_prob=0.2,
                         straggler_mean_delay=2.0, round_deadline=3.0,
                         corrupt_prob=0.02)
        eng = SimEngine(model, data, dp, cl, n_local_batches=2,
                        availability=0.5, rounds_per_call=rpc,
                        fault_config=fc)
        state = eng.init_state(model.init(jax.random.PRNGKey(1)), seed=0)
        state, _ = eng.run(state, warmup)
        t0 = time.perf_counter()
        state, hist = eng.run(state, rounds)
        jax.block_until_ready(state.params)
        rps = rounds / (time.perf_counter() - t0)
        committed = hist["committed"].astype(bool)
        selected = int(hist["n_selected"].sum())
        useful = int(hist["n_clients"][committed].sum())
        derived = (f"rounds_per_sec={rps:.3f};"
                   f"committed_frac={committed.mean():.3f};"
                   f"wasted_work_frac={1 - useful / selected:.3f};"
                   f"report_goal={eng.report_goal};"
                   f"over_selected={eng.sel_cohort}")
        emit(f"sim_engine/faults/cohort={cohort}/dropout={p}",
             1e6 / rps, derived)
        results[p] = rps
    return results


def run(dry_run: bool = False, shards=(1, 2, 4, 8)):
    cohorts = [8] if dry_run else [50, 200, 1000]
    host_rounds = 2 if dry_run else 5
    eng_rounds = 4 if dry_run else 40
    n_dev = len(jax.devices())
    shard_counts = [s for s in shards if s <= n_dev]
    skipped = [s for s in shards if s > n_dev]
    if skipped:
        print(f"bench_sim_engine: skipping shard counts {skipped} "
              f"(only {n_dev} devices visible; set XLA_FLAGS="
              f"--xla_force_host_platform_device_count={max(shards)})")
    results = {}
    for cohort in cohorts:
        n_users = max(6 * cohort, 50)
        cfg, model, ds = _setup(n_users)
        dp = DPConfig(clients_per_round=cohort, noise_multiplier=0.3,
                      clip_norm=0.8, server_opt="momentum", server_lr=0.5,
                      server_momentum=0.9)
        cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)

        # status quo: default availability (0.1) → the check-in pool dips
        # below qN → cohort shape changes → re-trace nearly every round
        host = FederatedTrainer(model, ds, dp, cl, n_local_batches=2,
                                seed=0, backend="host")
        host_rps = _rounds_per_sec(host, 1, host_rounds)
        emit(f"sim_engine/host/cohort={cohort}", 1e6 / host_rps,
             f"rounds_per_sec={host_rps:.3f}")

        # steady-state host: cohort always exactly qN, single compile
        pop = PopulationSim(n_users, availability=0.5, seed=0)
        host_fix = FederatedTrainer(model, ds, dp, cl, pop=pop,
                                    n_local_batches=2, seed=0,
                                    backend="host")
        fix_rps = _rounds_per_sec(host_fix, 1, host_rounds)
        emit(f"sim_engine/host_fixed_cohort/cohort={cohort}", 1e6 / fix_rps,
             f"rounds_per_sec={fix_rps:.3f}")

        eng = FederatedTrainer(model, ds, dp, cl,
                               pop=PopulationSim(n_users, availability=0.5,
                                                 seed=0),
                               n_local_batches=2, seed=0, backend="engine",
                               rounds_per_call=min(20, eng_rounds))
        eng_rps = _rounds_per_sec(eng, min(20, eng_rounds), eng_rounds)
        speedup = eng_rps / host_rps
        emit(f"sim_engine/compiled/cohort={cohort}", 1e6 / eng_rps,
             f"rounds_per_sec={eng_rps:.3f};speedup_vs_host={speedup:.2f}x;"
             f"speedup_vs_fixed_cohort_host={eng_rps / fix_rps:.2f}x")
        results[cohort] = (host_rps, eng_rps, speedup)

        # sharded cohort axis: rounds/sec per shard count. num_shards=1 IS
        # the `eng` run above (the canonical-reduction engine without
        # shard_map), so reuse its measurement instead of re-benchmarking.
        if 1 in shard_counts:
            emit(f"sim_engine/sharded/cohort={cohort}/shards=1",
                 1e6 / eng_rps, f"rounds_per_sec={eng_rps:.3f};"
                 "vs_unsharded=1.00x")
            results[(cohort, 1)] = eng_rps
        for s in (c for c in shard_counts if c > 1):
            sh = FederatedTrainer(model, ds, dp, cl,
                                  pop=PopulationSim(n_users,
                                                    availability=0.5,
                                                    seed=0),
                                  n_local_batches=2, seed=0,
                                  backend="engine", num_shards=s,
                                  rounds_per_call=min(20, eng_rounds))
            sh_rps = _rounds_per_sec(sh, min(20, eng_rounds), eng_rounds)
            emit(f"sim_engine/sharded/cohort={cohort}/shards={s}",
                 1e6 / sh_rps,
                 f"rounds_per_sec={sh_rps:.3f};"
                 f"vs_unsharded={sh_rps / eng_rps:.2f}x")
            results[(cohort, s)] = sh_rps
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny cohort/rounds smoke for CI (includes one "
                         "streaming-vs-materializing chunk record)")
    ap.add_argument("--shards", default="1,2,4,8",
                    help="comma-separated shard counts to sweep (counts "
                         "above the visible device count are skipped)")
    ap.add_argument("--chunk-sweep", action="store_true",
                    help="sweep cohort_chunk at cohorts {200, 1000, 5000}: "
                         "rounds/sec (steady-state, compile split out) + "
                         "peak live-buffer bytes per record")
    ap.add_argument("--population-sweep", action="store_true",
                    help="sweep population size 10^3 → 10^6 with the "
                         "streamed (host-resident corpus) backend vs the "
                         "device-resident reference: rounds/sec + device/"
                         "host corpus residency per record")
    ap.add_argument("--pod-sweep", action="store_true",
                    help="sweep (pods, shards) topologies of the 2-D "
                         "(pod, data) cohort mesh: rounds/sec per grid "
                         "point (force 16 devices on CPU for the full "
                         "grid)")
    ap.add_argument("--fault-sweep", action="store_true",
                    help="sweep dropout rate under the production fault "
                         "model (over-selection + report goals): rounds/sec "
                         "+ committed/wasted-work fractions per record")
    ap.add_argument("--client-step", action="store_true",
                    help="only the client-step microbench (µs per local-SGD "
                         "step, per cell_path)")
    args = ap.parse_args()
    if args.client_step:
        client_step_bench(dry_run=args.dry_run)
    elif args.population_sweep:
        population_sweep(dry_run=args.dry_run)
    elif args.fault_sweep:
        fault_sweep(dry_run=args.dry_run)
    else:
        if not (args.chunk_sweep or args.pod_sweep):
            run(dry_run=args.dry_run,
                shards=tuple(int(s) for s in args.shards.split(",") if s))
        if args.chunk_sweep or args.dry_run:
            chunk_sweep(dry_run=args.dry_run)
        if args.pod_sweep or args.dry_run:
            pod_sweep(dry_run=args.dry_run)
        if args.dry_run:
            population_sweep(dry_run=True)
            fault_sweep(dry_run=True)
        client_step_bench(dry_run=args.dry_run)
