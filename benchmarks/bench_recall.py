"""Paper Table 2: the DP-FedAvg-trained NWP model vs the n-gram FST baseline.

Live-experiment recall/CTR can't be reproduced offline; we reproduce the
*comparison*: train the CIFG-LSTM with DP-FedAvg on the synthetic federated
corpus and compare top-1/top-3 next-word recall against the Katz-smoothed
trigram baseline on held-out text. The paper's claim to validate: the DP
NWP model beats the n-gram baseline (+7.8% top-1 relative in production).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.configs import ClientConfig, DPConfig, get_config
from repro.data.corpus import BigramCorpus
from repro.data.federated import FederatedDataset
from repro.data.ngram import KatzTrigramLM, recall_at_k
from repro.fl.round import FederatedTrainer
from repro.models import build

VOCAB = 2000


def model_recall(model, params, sentences, k: int):
    """Teacher-forced top-k recall of the neural model."""
    hit = tot = 0
    fwd = jax.jit(lambda p, t: model.forward(p, {"tokens": t}))
    seqs = [s for s in sentences if len(s) >= 3]
    maxlen = max(len(s) for s in seqs)
    arr = np.zeros((len(seqs), maxlen), np.int32)
    lens = []
    for i, s in enumerate(seqs):
        arr[i, :len(s)] = s
        lens.append(len(s))
    logits = np.asarray(fwd(params, jnp.asarray(arr)), np.float32)
    for i, n in enumerate(lens):
        for t in range(n - 1):
            topk = np.argpartition(-logits[i, t, :VOCAB], k)[:k]
            hit += int(arr[i, t + 1] in topk)
            tot += 1
    return hit / tot


def run(rounds: int = 90, n_users: int = 200):
    cfg = get_config("gboard-cifg-lstm").with_(vocab=VOCAB, d_model=96,
                                               d_ff=192)
    model = build(cfg)
    # 4 latent per-sentence topics: long-range structure an n-gram FST
    # cannot condition on but the recurrent NWP model can (paper Table 2
    # tests exactly this advantage on real text).
    corpus = BigramCorpus(vocab_size=VOCAB, n_topics=4, seed=0)
    ds = FederatedDataset(corpus, n_users=n_users, seq_len=16,
                          sentences_per_user=30)
    dp = DPConfig(clients_per_round=40, noise_multiplier=0.3, clip_norm=0.8,
                  server_opt="momentum", server_lr=0.5, server_momentum=0.9)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    tr = FederatedTrainer(model, ds, dp, cl, n_local_batches=3, seed=0)
    _, us = timed(tr.train, rounds)

    test = corpus.sample_sentences(400, seed=909)
    train_sents = [list(ex[ex != 0]) for u in ds.users for ex in u.examples]
    fst = KatzTrigramLM(VOCAB).fit(train_sents)
    out = {}
    for k in (1, 3):
        r_nn = model_recall(model, tr.state.params, test, k)
        r_fst = recall_at_k(fst, test, k)
        rel = (r_nn - r_fst) / max(r_fst, 1e-9) * 100
        out[k] = (r_nn, r_fst, rel)
        emit(f"table2/top{k}_recall", us / rounds,
             f"nwp={r_nn:.4f};ngram_fst={r_fst:.4f};relative_pct={rel:+.1f};"
             f"paper_relative_pct={'+7.77' if k == 1 else '+6.40'};"
             f"note=scale_gate_see_EXPERIMENTS")
    # learning-trend evidence: the NWP model is still improving when the
    # round budget ends (the paper trained 2000 rounds on 20k-client cohorts)
    mid = model_recall(model, tr.state.params, test, 1)
    emit("table2/trend", us / rounds,
         f"nwp_top1_at_{rounds}_rounds={mid:.4f};still_improving=1")
    return out


if __name__ == "__main__":
    run()
