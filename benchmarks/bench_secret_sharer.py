"""Paper Table 4: unintended-memorization grid. Reduced-scale reproduction:
train the CIFG-LSTM with DP-FedAvg on a population containing secret-sharing
synthetic devices (always available, no Pace Steering), then measure
Random-Sampling rank and Beam-Search extraction per (n_u, n_e) config.

Expectation from the paper: low (n_u·n_e) ⇒ far from memorized;
high n_u AND n_e ⇒ rank→1 and beam-extractable."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, timed
from repro.configs import ClientConfig, DPConfig, get_config
from repro.core.secret_sharer import (canary_extracted, make_canaries,
                                      random_sampling_rank)
from repro.data.corpus import BigramCorpus
from repro.data.federated import FederatedDataset
from repro.fl.round import FederatedTrainer
from repro.models import build

VOCAB = 1000
# reduced grid: one canary per config, scaled-down n_e
GRID = [(1, 1), (1, 20), (4, 20), (16, 1), (16, 20)]


def run(rounds: int = 70, n_users: int = 250, rs_samples: int = 10_000):
    cfg = get_config("gboard-cifg-lstm").with_(vocab=VOCAB, d_model=64,
                                               d_ff=128)
    model = build(cfg)
    corpus = BigramCorpus(vocab_size=VOCAB, seed=0)
    ds = FederatedDataset(corpus, n_users=n_users, seq_len=16,
                          sentences_per_user=30)
    canaries = make_canaries(jax.random.PRNGKey(42), vocab=VOCAB,
                             grid=GRID, per_config=1)
    ds.inject_canaries(canaries)
    dp = DPConfig(clients_per_round=40, noise_multiplier=0.3, clip_norm=0.8,
                  server_opt="momentum", server_lr=0.5, server_momentum=0.9)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    tr = FederatedTrainer(model, ds, dp, cl, n_local_batches=3, seed=0)
    _, us = timed(tr.train, rounds)

    results = {}
    for c in canaries:
        rank = random_sampling_rank(model, tr.state.params, c,
                                    jax.random.PRNGKey(7),
                                    n_samples=rs_samples, batch_size=2048)
        extracted = canary_extracted(model, tr.state.params, c)
        results[(c.n_u, c.n_e)] = (rank, extracted)
        emit(f"table4/nu={c.n_u}_ne={c.n_e}", us / rounds,
             f"rs_rank={rank}/{rs_samples};beam_extracted={int(extracted)}")
    return results


if __name__ == "__main__":
    run()
