"""Paper Table 4: unintended-memorization grid, engine-backed.

Reduced-scale reproduction: train the CIFG-LSTM with DP-FedAvg on a
population containing secret-sharing synthetic devices (always available,
exempt from the Pace-Steering weight hook), then measure Random-Sampling
rank and Beam-Search extraction per (n_u, n_e) config.

The sweep runs on the compiled simulation engine
(`FederatedTrainer(backend="engine")`): K rounds per jit call, with the
in-scan canary hook (`canary_eval_fn`) recording the memorization-vs-round
log-perplexity curve for every canary while training, and the batched
`random_sampling_ranks` kernel scoring the whole grid against one shared
random-continuation pool.

The population is availability-limited like the paper's (§V-A): the
check-in pool (E ≈ 158 devices) sits *below* the configured cohort (200).
The host reference loop shrinks rounds to the fluctuating pool — so its
stacked client tensor changes shape and it re-traces jit round after round,
which is exactly the sweep-driver regime the engine replaces (fixed-size
top-up rounds, one compile; `SimEngine` warns about the σ implication).
A short host probe on the same configuration measures the engine-vs-host
rounds/sec speedup (acceptance: ≥3×).

Expectation from the paper: low (n_u·n_e) ⇒ far from memorized; the top
(n_u, n_e) config ⇒ RS rank → 0.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, timed
from repro.configs import ClientConfig, DPConfig, get_config
from repro.core.secret_sharer import (canary_eval_fn, canary_extracted,
                                      make_canaries, random_sampling_ranks)
from repro.data.corpus import BigramCorpus
from repro.data.federated import FederatedDataset
from repro.fl.round import FederatedTrainer
from repro.models import build

VOCAB = 300
# reduced grid: one canary per config; n_e scaled so the canary still makes
# up a memorizable fraction of the (10-example) local batches drawn from the
# 200-example synthetic shards
GRID = [(1, 1), (1, 50), (4, 50), (16, 1), (16, 50)]
EVAL_EVERY = 25


def _setup(n_users: int):
    cfg = get_config("gboard-cifg-lstm").with_(vocab=VOCAB, d_model=24,
                                               d_ff=48)
    model = build(cfg)
    corpus = BigramCorpus(vocab_size=VOCAB, seed=0)
    ds = FederatedDataset(corpus, n_users=n_users, seq_len=16,
                          sentences_per_user=30)
    canaries = make_canaries(jax.random.PRNGKey(42), vocab=VOCAB,
                             grid=GRID, per_config=1)
    ds.inject_canaries(canaries)
    dp = DPConfig(clients_per_round=200, noise_multiplier=0.3, clip_norm=0.8,
                  server_opt="momentum", server_lr=0.5, server_momentum=0.9)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    return model, ds, canaries, dp, cl


def run(rounds: int = 300, n_users: int = 1_200, rs_samples: int = 10_000,
        host_probe_rounds: int = 4):
    model, ds, canaries, dp, cl = _setup(n_users)

    # host-loop probe: same config, a few timed rounds after one warmup
    host = FederatedTrainer(model, ds, dp, cl, n_local_batches=1, seed=0,
                            backend="host")
    host.train(1)
    _, probe_us = timed(host.train, host_probe_rounds)
    host_rps = host_probe_rounds / (probe_us / 1e6)

    # the real sweep: compiled engine + in-scan canary hook
    tr = FederatedTrainer(model, ds, dp, cl, n_local_batches=1, seed=0,
                          backend="engine", rounds_per_call=EVAL_EVERY,
                          eval_fn=canary_eval_fn(model, canaries),
                          eval_every=EVAL_EVERY)
    tr.train(EVAL_EVERY)                       # compile + steady state
    t0 = time.perf_counter()
    tr.train(rounds - EVAL_EVERY)
    eng_rps = (rounds - EVAL_EVERY) / (time.perf_counter() - t0)
    speedup = eng_rps / host_rps
    emit("table4/engine_speedup", 1e6 / eng_rps,
         f"rounds_per_sec={eng_rps:.3f};host_rounds_per_sec={host_rps:.3f};"
         f"speedup_vs_host={speedup:.2f}x")

    # memorization-vs-round curve from the in-scan hook
    ev = tr.eval_history
    curve = ev["values"]["canary_logppl"][ev["mask"]]     # (n_evals, K)
    eval_rounds = ev["round"][ev["mask"]]

    ranks = random_sampling_ranks(model, tr.state.params, canaries,
                                  jax.random.PRNGKey(7),
                                  n_samples=rs_samples, batch_size=2048)
    results = {}
    for k, c in enumerate(canaries):
        extracted = canary_extracted(model, tr.state.params, c)
        results[(c.n_u, c.n_e)] = (int(ranks[k]), extracted)
        emit(f"table4/nu={c.n_u}_ne={c.n_e}", 1e6 / eng_rps,
             f"rs_rank={int(ranks[k])}/{rs_samples};"
             f"beam_extracted={int(extracted)};"
             f"logppl_round{int(eval_rounds[0])}={curve[0, k]:.2f};"
             f"logppl_round{int(eval_rounds[-1])}={curve[-1, k]:.2f}")
    return results


if __name__ == "__main__":
    run()
