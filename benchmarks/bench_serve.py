"""Closed-loop serving traffic benchmark for the continuous-batching
engine (`repro.serve.ServeEngine`).

A closed-loop driver keeps a fixed number of sessions in flight against
the paper's production NWP model (1.3M-param CIFG-LSTM): each completed
suggestion-strip session is immediately replaced by a fresh one until the
target session count drains, so the engine runs at the offered concurrency
the whole window. Per concurrency level it reports:

* **p50 / p99 session latency** (submit → final token, including queue
  wait — the suggestion-strip user experience), emitted with p50 as the
  record's ``us_per_call``;
* **QPS** (completed sessions/sec) and **tokens/sec** (decode throughput);
* **p50 / p99 admission latency** (prefill + first token + slot scatter)
  as a separate ``serve/admission/...`` record. Prompt lengths are drawn
  from 2..MAX_PROMPT so the engine's power-of-two bucketed admission is
  actually exercised: without bucketing every distinct length is its own
  prefill compile and the p99 blows up on the first occurrence of each;

and once per run a **checkpoint hot-swap drill**: with sessions in flight,
a perturbed checkpoint is written to disk and promoted through
``engine.load_checkpoint`` (the full DP-round → serving promotion path);
the drill asserts **zero dropped sessions** and records the swap pause and
how many sessions rode across the boundary.

    PYTHONPATH=src:. python benchmarks/bench_serve.py [--dry-run]
    BENCH_JSON=BENCH_serve.json PYTHONPATH=src:. \
        python benchmarks/bench_serve.py          # archive the sweep

``--dry-run`` shrinks the model and the sweep to a seconds-long CI smoke
(still ≥3 concurrency levels + the drill, so `tools/ci.sh` can assert the
``serve/...`` records in ``BENCH_ci.json``).
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.models import build
from repro.serve import NwpRequest, ServeEngine
from repro.train import checkpoint

MIN_PROMPT = 2
MAX_PROMPT = 12
TOP_K = 3


def _setup(dry_run: bool):
    cfg = get_config("gboard-cifg-lstm")
    if dry_run:
        cfg = cfg.with_(vocab=300, d_model=32, d_ff=64)
    model = build(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _submit_fresh(engine, rng, vocab, steps, temperature, uid, length=None):
    if length is None:
        length = int(rng.integers(MIN_PROMPT, MAX_PROMPT + 1))
    prompt = (2,) + tuple(int(t) for t in
                          rng.integers(4, vocab, length - 1))
    engine.submit(NwpRequest(
        prompt=prompt, steps=steps, temperature=temperature,
        seed=int(uid) if temperature > 0 else None,
        session_id=f"bench-{uid}"))


def closed_loop(model, params, *, concurrency: int, total: int, steps: int,
                temperature: float = 0.7, seed: int = 0):
    """Drive ``total`` sessions at a steady ``concurrency``; returns the
    latency/throughput stats of the steady-state window (a full
    ``concurrency`` worth of warm-up sessions runs first so compile time
    never lands in a timed session)."""
    engine = ServeEngine(model, params, max_slots=concurrency, top_k=TOP_K)
    rng = np.random.default_rng(seed)
    vocab = model.cfg.vocab

    # warm-up: compile admission/tick off the clock for *every* prompt
    # length in the mix (every pow2 bucket when bucketed; every distinct
    # length on the exact-length fallback path)
    warm_lens = list(range(MIN_PROMPT, MAX_PROMPT + 1))
    while len(warm_lens) < concurrency:
        warm_lens.append(int(rng.integers(MIN_PROMPT, MAX_PROMPT + 1)))
    for i, wl in enumerate(warm_lens):
        _submit_fresh(engine, rng, vocab, steps, temperature, 10**9 + i,
                      length=wl)
    engine.run()
    engine.pop_completed()
    n_warm_adm = len(engine.admission_times_s)

    submitted = completed = tokens = 0
    latencies = []
    t0 = time.perf_counter()
    while completed < total:
        while submitted < total and engine.in_flight < concurrency:
            _submit_fresh(engine, rng, vocab, steps, temperature, submitted)
            submitted += 1
        engine.step()
        for res in engine.pop_completed():
            assert res.status == "done"
            latencies.append(res.latency_s)
            tokens += len(res.tokens)
            completed += 1
    wall = time.perf_counter() - t0
    lat_us = np.asarray(latencies) * 1e6
    adm_us = np.asarray(engine.admission_times_s[n_warm_adm:]) * 1e6
    return {"p50_us": float(np.percentile(lat_us, 50)),
            "p99_us": float(np.percentile(lat_us, 99)),
            "adm_p50_us": float(np.percentile(adm_us, 50)),
            "adm_p99_us": float(np.percentile(adm_us, 99)),
            "admissions": int(adm_us.shape[0]),
            "bucketed": bool(engine.bucketed_admission),
            "qps": completed / wall,
            "toks_per_s": tokens / wall,
            "wall_s": wall,
            "sessions": completed}


def hot_swap_drill(model, params, *, concurrency: int, steps: int,
                   seed: int = 7):
    """Promote a new checkpoint with a full complement of sessions in
    flight; returns (swap_us, stats). Asserts zero dropped sessions and
    that every in-flight session actually crossed the version boundary."""
    perturbed = jax.tree_util.tree_map(
        lambda a: a * (1.0 + 1e-3) if np.issubdtype(
            np.asarray(a).dtype, np.floating) else a, params)
    engine = ServeEngine(model, params, max_slots=concurrency, top_k=TOP_K)
    rng = np.random.default_rng(seed)
    vocab = model.cfg.vocab
    total = 2 * concurrency
    for i in range(total):
        _submit_fresh(engine, rng, vocab, steps, 0.7, i)
    for _ in range(max(1, steps // 2)):
        engine.step()
    in_flight = engine.active_sessions
    with tempfile.TemporaryDirectory() as tmp:
        ck = os.path.join(tmp, "promoted.msgpack")
        checkpoint.save(ck, perturbed, meta={"arch": model.cfg.name,
                                             "drill": "hot_swap"})
        t0 = time.perf_counter()
        version = engine.load_checkpoint(ck)
        swap_us = (time.perf_counter() - t0) * 1e6
    results = engine.run()
    done = [r for r in results.values() if r.status == "done"]
    dropped = total - len(done)
    assert dropped == 0, f"hot swap dropped {dropped} sessions"
    crossed = sum(1 for r in done
                  if set(r.params_versions) == {0, 1})
    return swap_us, {"sessions": total, "dropped": dropped,
                     "in_flight_at_swap": in_flight,
                     "crossed_boundary": crossed, "version": version}


def run(dry_run: bool = False):
    model, params = _setup(dry_run)
    sweep = [(2, 8), (4, 12), (8, 24)] if dry_run else \
        [(8, 64), (32, 192), (128, 512)]
    steps = 4 if dry_run else 8
    for concurrency, total in sweep:
        s = closed_loop(model, params, concurrency=concurrency,
                        total=total, steps=steps)
        emit(f"serve/latency/concurrency={concurrency}", s["p50_us"],
             f"p99_us={s['p99_us']:.0f};qps={s['qps']:.2f};"
             f"toks_per_s={s['toks_per_s']:.0f};steps={steps};"
             f"sessions={s['sessions']};slots={concurrency}")
        emit(f"serve/admission/concurrency={concurrency}", s["adm_p50_us"],
             f"p99_us={s['adm_p99_us']:.0f};"
             f"admissions={s['admissions']};"
             f"bucketed={int(s['bucketed'])};"
             f"prompt_lens={MIN_PROMPT}..{MAX_PROMPT}")
    drill_c = 4 if dry_run else 32
    swap_us, d = hot_swap_drill(model, params, concurrency=drill_c,
                                steps=steps)
    emit(f"serve/hot_swap/concurrency={drill_c}", swap_us,
         f"sessions={d['sessions']};dropped={d['dropped']};"
         f"in_flight_at_swap={d['in_flight_at_swap']};"
         f"crossed_boundary={d['crossed_boundary']};steps={steps}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny model + short sweep (CI smoke)")
    args = ap.parse_args()
    run(dry_run=args.dry_run)
