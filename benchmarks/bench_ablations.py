"""Paper Tables 6/7/8 + Fig. 1: hyperparameter ablations for DP-FedAvg on a
public corpus (the paper's privacy-free tuning methodology §III-A) —
server optimizer, client batch size/lr, clipping norm, and the
fraction-of-clients-clipped trajectory."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.configs import ClientConfig, DPConfig, get_config
from repro.data.corpus import BigramCorpus
from repro.data.federated import FederatedDataset, held_out_batch
from repro.fl.round import FederatedTrainer
from repro.models import build
from repro.models.layers import lm_loss

VOCAB = 1000
ROUNDS = 20


def _setup():
    cfg = get_config("gboard-cifg-lstm").with_(vocab=VOCAB, d_model=48,
                                               d_ff=96)
    model = build(cfg)
    corpus = BigramCorpus(vocab_size=VOCAB, seed=0)
    ds = FederatedDataset(corpus, n_users=200, seq_len=16,
                          sentences_per_user=30)
    return cfg, model, corpus, ds


def _recall_top1(cfg, model, params, corpus):
    hb = held_out_batch(corpus, 256, 16)
    import jax
    logits = np.asarray(model.forward(params,
                                      {"tokens": jnp.asarray(hb["tokens"])}),
                        np.float32)
    pred = logits[:, :, :VOCAB].argmax(-1)
    mask = hb["mask"] > 0
    return float((pred[mask] == hb["labels"][mask]).mean())


def _train(cfg, model, corpus, ds, dp, cl, rounds=ROUNDS):
    # compiled multi-round engine: the whole ablation grid shares its
    # per-shape compile cache, so each sweep point pays jit once; ample
    # availability so fixed-size rounds never outrun the check-in pool
    from repro.fl.population import PopulationSim
    pop = PopulationSim(len(ds.users), availability=0.5, seed=0)
    tr = FederatedTrainer(model, ds, dp, cl, pop=pop, n_local_batches=2,
                          seed=0, backend="engine", rounds_per_call=rounds)
    hist = tr.train(rounds)
    return tr, _recall_top1(cfg, model, tr.state.params, corpus), hist


def run():
    cfg, model, corpus, ds = _setup()
    base = dict(clients_per_round=30, noise_multiplier=0.3, clip_norm=0.8)
    results = {}

    # Table 6: server optimizer
    for opt, lr, mu in [("sgd", 0.5, 0.0), ("momentum", 0.5, 0.9),
                        ("adam", 0.002, 0.0)]:
        dp = DPConfig(server_opt=opt, server_lr=lr, server_momentum=mu, **base)
        cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
        (_, recall, _), us = timed(lambda: _train(cfg, model, corpus, ds, dp, cl))
        results[f"opt={opt}"] = recall
        emit(f"table6/server_opt={opt}", us / ROUNDS,
             f"top1_recall={recall:.4f}")

    # Table 7: client batch size (paper: recall insensitive to |b|)
    for b, lr in [(5, 0.2), (10, 0.3), (20, 0.3)]:
        dp = DPConfig(server_opt="momentum", server_lr=0.5,
                      server_momentum=0.9, **base)
        cl = ClientConfig(local_epochs=1, batch_size=b, lr=lr)
        (_, recall, _), us = timed(lambda: _train(cfg, model, corpus, ds, dp, cl))
        results[f"b={b}"] = recall
        emit(f"table7/client_batch={b}", us / ROUNDS,
             f"top1_recall={recall:.4f}")

    # Table 8 + Fig 1: clipping norm sweep → recall + frac-clipped trajectory
    for S in (0.1, 0.8, 2.0):
        dp = DPConfig(server_opt="momentum", server_lr=0.5,
                      server_momentum=0.9, clients_per_round=30,
                      noise_multiplier=0.3, clip_norm=S)
        cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
        ((tr, recall, hist)), us = timed(
            lambda: _train(cfg, model, corpus, ds, dp, cl))
        frac_first = np.mean([h["frac_clipped"] for h in hist[:5]])
        frac_last = np.mean([h["frac_clipped"] for h in hist[-5:]])
        results[f"S={S}"] = recall
        emit(f"table8/clip_norm={S}", us / ROUNDS,
             f"top1_recall={recall:.4f};fig1_frac_clipped_first5={frac_first:.2f};"
             f"last5={frac_last:.2f}")
    return results


if __name__ == "__main__":
    run()
