"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. Tables covered:
  Table 2 → bench_recall          (NWP vs Katz n-gram baseline)
  Table 3 → bench_canary_exposure (participation / canary encounters)
  Table 4 → bench_secret_sharer   (memorization grid, reduced scale)
  Table 5 → bench_accounting      (hypothetical (ε,δ) bounds)
  Tables 6/7/8 + Fig 1 → bench_ablations
  (ours)  → bench_kernels, roofline (§Roofline terms per arch × shape)
  (ours)  → bench_sim_engine (compiled vs host-loop simulation throughput)
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: accounting,recall,"
                         "ablations,canary,secret_sharer,kernels,roofline,"
                         "sim_engine")
    ap.add_argument("--skip-slow", action="store_true",
                    help="skip the two multi-minute training benches")
    args = ap.parse_args()

    from benchmarks import (bench_accounting, bench_ablations,
                            bench_canary_exposure, bench_kernels,
                            bench_recall, bench_secret_sharer,
                            bench_sim_engine, roofline)

    benches = {
        "accounting": bench_accounting.run,
        "canary": bench_canary_exposure.run,
        "kernels": bench_kernels.run,
        "roofline": roofline.run,
        "recall": bench_recall.run,
        "ablations": bench_ablations.run,
        "secret_sharer": bench_secret_sharer.run,
        "sim_engine": bench_sim_engine.run,
    }
    slow = {"recall", "ablations", "secret_sharer", "sim_engine"}
    selected = (args.only.split(",") if args.only else list(benches))

    print("name,us_per_call,derived")
    failures = []
    for name in selected:
        if args.skip_slow and name in slow:
            continue
        try:
            benches[name]()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"FAILED benches: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
