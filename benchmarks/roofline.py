"""§Roofline: three-term roofline per (arch × shape × mesh) from the
compiled dry-run artifacts + an analytic workload model.

    compute term    = FLOPs / (chips × 197 TFLOP/s bf16)
    memory term     = HBM bytes / (chips × 819 GB/s)
    collective term = collective bytes / (chips × 50 GB/s/link)

Two sources are combined and both reported:
  * ``experiments/dryrun/*.json`` — ``cost_analysis()`` flops/bytes and the
    optimized-HLO collective ops. CAVEAT (recorded per row): XLA cost
    analysis counts ``while``-loop (lax.scan) bodies ONCE, so compiled
    numbers undercount by the trip counts (microbatch × layer scans). They
    are reported raw, as the *per-iteration schedule*.
  * an analytic workload model (this file) with explicit trip counts —
    MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE), attention/SSD extras,
    FSDP/TP/DP collective volumes from the sharding scheme in
    ``sharding/specs.py``. These drive the roofline terms.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Optional

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.base import InputShape, ModelConfig
from repro.models.layers import pad_vocab

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s/link ICI

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

LONG_WINDOW = 4096


# ---------------------------------------------------------------------------
# analytic workload model
# ---------------------------------------------------------------------------


def param_counts(cfg: ModelConfig) -> Dict[str, float]:
    """Total and per-token-active parameter counts (analytic)."""
    d, L, ff, Vp = cfg.d_model, cfg.n_layers, cfg.d_ff, pad_vocab(cfg.vocab)
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    attn = d * H * hd + 2 * d * KV * hd + H * hd * d
    embed = Vp * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family in ("dense", "vlm"):
        mlp = 3 * d * ff
        total = L * (attn + mlp) + embed
        active = total
    elif cfg.family == "moe":
        expert = 3 * d * cfg.expert_d_ff
        router = d * cfg.n_experts
        total = L * (attn + cfg.n_experts * expert + router) + embed
        active = L * (attn + cfg.top_k * expert + router) + embed
    elif cfg.family == "ssm":
        di, N, Hs = cfg.ssm_expand * d, cfg.ssm_state, cfg.ssm_heads
        mixer = 2 * d * di + 2 * d * N + d * Hs + di * d
        total = L * mixer + embed
        active = total
    elif cfg.family == "hybrid":
        di, N = cfg.ssm_expand * d, cfg.ssm_state
        mixer = 2 * d * di + 2 * d * N + d * cfg.ssm_heads + di * d
        shared = attn + 3 * d * ff
        total = L * mixer + shared + embed
        # the shared block's weights are *applied* at every site
        active = L * mixer + (L // cfg.hybrid_attn_every) * shared + embed
    elif cfg.family == "encdec":
        enc = cfg.n_enc_layers * (attn + 3 * d * ff)
        dec = L * (attn + (d * H * hd + 2 * d * KV * hd + H * hd * d)
                   + 3 * d * ff)
        total = enc + dec + embed
        active = total
    else:  # lstm
        total = Vp * d + (d + ff) * 3 * ff + ff * d
        active = total
    return {"total": float(total), "active": float(active),
            "embed": float(embed)}


def _attn_flops_per_token(cfg: ModelConfig, ctx: int, window: int) -> float:
    """QK^T + PV flops per token at average context ``ctx``."""
    if cfg.family == "ssm":
        return 0.0
    eff = min(ctx, window) if window > 0 else ctx
    per_layer = 4.0 * eff * cfg.n_heads * cfg.head_dim
    n_attn = (cfg.n_layers // cfg.hybrid_attn_every
              if cfg.family == "hybrid" else cfg.n_layers)
    if cfg.family == "encdec":
        per_layer += 4.0 * cfg.n_audio_frames * cfg.n_heads * cfg.head_dim
    return per_layer * n_attn


def _ssd_flops_per_token(cfg: ModelConfig, chunk: int = 128) -> float:
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    di = cfg.ssm_expand * cfg.d_model
    N, Hs = cfg.ssm_state, cfg.ssm_heads
    p = di // Hs
    # dual form: CBᵀ (Q·N), weighted X (Q·p), state in/out (p·N each)
    per_layer = 2.0 * Hs * (chunk * N + chunk * p + 2 * p * N)
    return per_layer * cfg.n_layers


@dataclass
class Workload:
    flops: float             # global per step
    hbm_bytes: float         # global per step
    coll_bytes: float        # per chip per step (ICI)
    model_flops: float       # 6·N_active·D convention


def analytic_workload(cfg: ModelConfig, shape: InputShape, chips: int,
                      data_par: int, model_par: int) -> Workload:
    pc = param_counts(cfg)
    P, Pa = pc["total"], pc["active"]
    B, S = shape.global_batch, shape.seq_len
    Vp = pad_vocab(cfg.vocab)
    d = cfg.d_model
    window = cfg.attn_window

    if shape.kind == "train":
        tokens = B * S
        model_flops = 6.0 * Pa * tokens
        # fwd+bwd (3×) + remat second fwd (≈1×) + attention + head
        flops = (8.0 * Pa + 3.0 * (_attn_flops_per_token(cfg, S / 2, window)
                                   + _ssd_flops_per_token(cfg))) * tokens
        n_micro = B // data_par
        act = tokens * d * cfg.n_layers * 2.0 * 6  # bf16 residual-ish traffic
        hbm = n_micro * 2 * (2 * P) + act + 4 * (4 * P)  # wt reads + opt
        # per chip: FSDP gather (bf16 wts per microbatch) + TP act all-reduce
        # + grad reduce-scatter + cross-pod round sum (multi-pod only)
        fsdp = n_micro * (2 * P) / model_par
        tp = n_micro * 2 * 2 * cfg.n_layers * (S * d * 2) / 1  # per client
        rs = n_micro * (4 * P) / model_par
        pods = chips // (data_par * model_par)
        xpod = (4 * P) / (data_par * model_par) * (pods - 1)
        coll = fsdp + tp + rs + xpod
    elif shape.kind == "prefill":
        tokens = B * S
        model_flops = 2.0 * Pa * tokens
        flops = (2.0 * Pa + _attn_flops_per_token(cfg, S / 2, window)
                 + _ssd_flops_per_token(cfg)) * tokens
        kv_write = (2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim
                    * tokens * 2)
        act = tokens * d * cfg.n_layers * 2.0 * 4
        hbm = 2 * P + act + kv_write
        coll = (2 * P) / model_par + 2 * cfg.n_layers * (
            B * S * d * 2) / data_par / model_par * 2
    else:  # decode: ONE token per sequence
        tokens = B
        model_flops = 2.0 * Pa * tokens
        ctx = min(S, window) if window > 0 else S
        flops = (2.0 * Pa + _attn_flops_per_token(cfg, ctx, window)
                 + _ssd_flops_per_token(cfg) / 128) * tokens
        cache = cache_bytes(cfg, shape)
        hbm = 2 * P + cache
        coll = (2 * P) / model_par + 2 * cfg.n_layers * (B * d * 2) * 2
    return Workload(flops=flops, hbm_bytes=hbm, coll_bytes=coll,
                    model_flops=model_flops)


def cache_bytes(cfg: ModelConfig, shape: InputShape) -> float:
    S = shape.seq_len
    if cfg.attn_window > 0:
        S = min(S, cfg.attn_window)
    B = shape.global_batch
    kv = 2 * cfg.n_layers * S * cfg.n_kv_heads * cfg.head_dim * 2 * B
    if cfg.family == "ssm":
        di = cfg.ssm_expand * cfg.d_model
        return (cfg.n_layers * B * (di // cfg.ssm_heads) * cfg.ssm_heads
                * cfg.ssm_state * 4)
    if cfg.family == "hybrid":
        di = cfg.ssm_expand * cfg.d_model
        ssm = cfg.n_layers * B * di * cfg.ssm_state * 4
        sites = cfg.n_layers // cfg.hybrid_attn_every
        return ssm + 2 * sites * shape.seq_len * cfg.n_kv_heads \
            * cfg.head_dim * 2 * B
    return kv


# ---------------------------------------------------------------------------
# table construction
# ---------------------------------------------------------------------------


def load_dryrun(arch: str, shape: str, mesh: str) -> Optional[dict]:
    f = DRYRUN_DIR / f"{arch}__{shape}__{mesh}.json"
    return json.loads(f.read_text()) if f.exists() else None


def roofline_row(arch: str, shape_name: str, mesh: str = "16x16") -> dict:
    from repro.launch.dryrun import arch_for_shape
    shape = INPUT_SHAPES[shape_name]
    cfg = arch_for_shape(get_config(arch), shape)
    chips = 512 if mesh == "2x16x16" else 256
    data_par = 16
    model_par = 16
    w = analytic_workload(cfg, shape, chips, data_par, model_par)
    t_comp = w.flops / (chips * PEAK_FLOPS)
    t_mem = w.hbm_bytes / (chips * HBM_BW)
    t_coll = w.coll_bytes / LINK_BW          # coll is already per-chip
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    rec = load_dryrun(arch, shape_name, mesh) or {}
    row = {
        "arch": arch, "shape": shape_name, "mesh": mesh,
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": w.model_flops,
        "analytic_flops": w.flops,
        "useful_ratio": w.model_flops / w.flops,
        "hlo_flops_periter": rec.get("cost", {}).get("flops"),
        "hlo_coll_bytes_periter": rec.get("collectives", {}).get("total_bytes"),
        "arg_gib": (rec.get("memory", {}).get("argument_size_in_bytes", 0)
                    or 0) / 2**30,
        "temp_gib": (rec.get("memory", {}).get("temp_size_in_bytes", 0)
                     or 0) / 2**30,
    }
    return row


WHAT_MOVES = {
    "compute": "more chips / lower precision / cut remat recompute",
    "memory": "KV-cache sharding+quantization, fewer weight re-reads "
              "(larger microbatch), fused kernels",
    "collective": "shrink FSDP gathers (TP-only serving weights), overlap "
                  "collectives with compute, keep round-sum intra-pod",
}


def build_table(archs=None, shapes=None, meshes=("16x16",)) -> list:
    from repro.configs import ASSIGNED_ARCHS
    rows = []
    for arch in archs or ASSIGNED_ARCHS:
        for shape in shapes or list(INPUT_SHAPES):
            for mesh in meshes:
                rows.append(roofline_row(arch, shape, mesh))
    return rows


def format_markdown(rows) -> str:
    out = ["| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
           "| bottleneck | MODEL_FLOPS | useful ratio |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['model_flops']:.2e} | {r['useful_ratio']:.2f} |")
    return "\n".join(out)


def run():
    from benchmarks.common import emit
    rows = build_table()
    for r in rows:
        emit(f"roofline/{r['arch']}/{r['shape']}", 0.0,
             f"compute={r['compute_s']:.3e};memory={r['memory_s']:.3e};"
             f"collective={r['collective_s']:.3e};dominant={r['dominant']};"
             f"useful={r['useful_ratio']:.2f}")
    out = Path(__file__).resolve().parents[1] / "experiments" / "roofline.md"
    out.parent.mkdir(exist_ok=True)
    out.write_text(format_markdown(rows) + "\n")
    return rows


if __name__ == "__main__":
    run()
