"""Shared benchmark plumbing: timing + CSV emission + machine-readable JSON.

Every ``emit()`` prints a ``name,us_per_call,derived`` CSV line; when the
``BENCH_JSON`` environment variable names a file, it *additionally* appends
one JSON record per line (``{"name", "us_per_call", "derived"}``) so CI can
archive the perf trajectory (`tools/ci.sh` writes ``BENCH_ci.json`` this
way and uploads it as an artifact).
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable


def timed(fn: Callable, *args, repeats: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6  # µs


def emit(name: str, us_per_call: float, derived):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
    path = os.environ.get("BENCH_JSON")
    if path:
        with open(path, "a") as f:
            f.write(json.dumps({"name": name,
                                "us_per_call": round(float(us_per_call), 1),
                                "derived": str(derived)}) + "\n")
