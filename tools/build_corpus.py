#!/usr/bin/env python
"""Build an on-disk population store for the streamed engine backend.

    PYTHONPATH=src python tools/build_corpus.py --out /data/pop_1m \
        --n-users 1000000 --vocab 2000 --seq-len 16 --shard-users 4096

Synthesizes a BigramCorpus-backed federated population (the same generator
the simulation's `FederatedDataset` uses, so small stores are bit-identical
to `to_device_arrays()` of the equivalent dataset) and serializes it to the
sharded mmap format of `repro.data.population_store`:

    out/
      meta.json                       version, n_users, emax, row_len, ...
      counts.npy                      (N,) int32 true example counts
      synthetic.npy                   (N,) bool secret-sharer mask
      examples-00000-of-00NNN.npy     (shard_users, E_max, seq_len+1) int32

Users are generated and written one shard at a time, so building a 10^6-user
store needs O(shard_users · E_max · seq_len) host memory, not O(N).

`--inject-canaries` appends the paper's secret-sharing synthetic devices
(n_u devices per canary, each holding n_e canary copies + public filler) at
the tail of the id space and writes the canary metadata to `canaries.json`
next to the store, since a store has no `FederatedDataset` to ask later.

`--replicate N` instead tiles a small synthesized base population to N users
via `ReplicatedPopulationStore` before writing — a fast way to build large
*throughput* corpora (secret-sharer semantics do not survive replication).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

from repro.data.corpus import BigramCorpus  # noqa: E402
from repro.data.federated import (USER_SENTENCES,  # noqa: E402
                                  FederatedDataset, sentences_to_examples)
from repro.data.population_store import (DEFAULT_SHARD_USERS,  # noqa: E402
                                         InMemoryPopulationStore,
                                         MmapPopulationStore,
                                         PopulationStore,
                                         ReplicatedPopulationStore,
                                         write_population_store)


def _dataset_store(args):
    """Small populations: go through FederatedDataset so the store is
    bit-identical to the simulation's in-memory path (incl. canaries).
    Returns ``(InMemoryPopulationStore, canaries)``."""
    corpus = BigramCorpus(vocab_size=args.vocab, seed=args.seed)
    ds = FederatedDataset(corpus, n_users=args.n_users, seq_len=args.seq_len,
                          sentences_per_user=args.sentences_per_user,
                          seed=args.seed)
    canaries = []
    if args.inject_canaries:
        import jax

        from repro.core.secret_sharer import make_canaries
        canaries = make_canaries(jax.random.PRNGKey(42), vocab=args.vocab)
        ds.inject_canaries(canaries)
    store = InMemoryPopulationStore.from_dataset(ds)
    return store, canaries


class _SynthesizedStore(PopulationStore):
    """Lazy per-shard synthesis for large --n-users: generates each user's
    sentences on first gather instead of holding the whole population.
    Deterministic in (seed, uid) — the same per-user seeds FederatedDataset
    uses — so a store built shard-by-shard equals one built in one shot."""

    def __init__(self, args):
        self.args = args
        self.corpus = BigramCorpus(vocab_size=args.vocab, seed=args.seed)
        self.n_users = args.n_users
        self.emax = min(args.sentences_per_user, USER_SENTENCES)
        self.row_len = args.seq_len + 1
        self.counts = np.full((self.n_users,), self.emax, np.int32)
        self.synthetic = np.zeros((self.n_users,), bool)

    def gather(self, ids) -> np.ndarray:
        ids = self._check_ids(ids)
        out = np.empty((ids.shape[0], self.emax, self.row_len), np.int32)
        a = self.args
        for i, uid in enumerate(ids):
            sents = self.corpus.sample_sentences(
                self.emax, seed=a.seed * 1_000_003 + int(uid))
            ex = sentences_to_examples(sents, a.seq_len, self.emax)
            out[i] = ex[np.resize(np.arange(ex.shape[0]), self.emax)]
        return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True, help="store directory to create")
    ap.add_argument("--n-users", type=int, default=1000)
    ap.add_argument("--vocab", type=int, default=2000)
    ap.add_argument("--seq-len", type=int, default=16)
    ap.add_argument("--sentences-per-user", type=int, default=30)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shard-users", type=int, default=DEFAULT_SHARD_USERS)
    ap.add_argument("--inject-canaries", action="store_true",
                    help="append secret-sharing devices and write "
                         "canaries.json (small populations only)")
    ap.add_argument("--replicate", type=int, default=None, metavar="N",
                    help="tile the synthesized base to N users before "
                         "writing (throughput corpora; breaks secret-sharer "
                         "semantics)")
    ap.add_argument("--dataset-path", action="store_true",
                    help="force the exact FederatedDataset construction "
                         "path even for large --n-users (O(N) host memory)")
    args = ap.parse_args()

    t0 = time.time()
    canaries = []
    if args.inject_canaries or args.dataset_path or args.n_users <= 20_000:
        store, canaries = _dataset_store(args)
    else:
        store = _SynthesizedStore(args)
    if args.replicate is not None:
        store = ReplicatedPopulationStore(store, args.replicate)

    path = write_population_store(args.out, store,
                                  shard_users=args.shard_users,
                                  seq_len=args.seq_len)
    if canaries:
        (path / "canaries.json").write_text(json.dumps(
            [{"prefix": list(c.prefix), "tokens": list(c.tokens),
              "n_u": c.n_u, "n_e": c.n_e} for c in canaries], indent=1))

    back = MmapPopulationStore(path)  # reopen = cheap structural validation
    payload = back.n_users * back.emax * back.row_len * 4
    print(f"wrote {back.n_users} users ({back.n_shards} shards, "
          f"E_max={back.emax}, seq_len={back.row_len - 1}, "
          f"{payload / 1e6:.1f} MB payload"
          + (f", {len(canaries)} canaries" if canaries else "")
          + f") to {path} in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
