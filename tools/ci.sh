#!/usr/bin/env bash
# Tier-1 CI gate: fast deterministic tests + a compiled-engine smoke.
#
#   tools/ci.sh            # tier-1 (< 2 min target) + engine bench smoke
#   tools/ci.sh --slow     # additionally run @pytest.mark.slow tests
#
# Test tiers (see ROADMAP.md):
#   tier-1: PYTHONPATH=src python -m pytest -x -q        — every PR, no
#           network, no hypothesis, deterministic seeds, CPU-only
#   slow:   pytest --runslow                              — compile sweeps,
#           long training runs; nightly / pre-release
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest -x -q =="
python -m pytest -x -q

if [[ "${1:-}" == "--slow" ]]; then
  echo "== slow tier: pytest --runslow =="
  python -m pytest -q --runslow -m slow
fi

echo "== smoke: compiled simulation engine benchmark (dry run) =="
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
  python benchmarks/bench_sim_engine.py --dry-run

echo "CI OK"
