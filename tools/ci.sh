#!/usr/bin/env bash
# Tier-1 CI gate: fast deterministic tests + a compiled-engine smoke.
#
#   tools/ci.sh            # tier-1 (< 2 min target) + engine bench smoke
#   tools/ci.sh --slow     # additionally run @pytest.mark.slow tests
#
# Test tiers (see ROADMAP.md):
#   tier-1: PYTHONPATH=src python -m pytest -x -q        — every PR, no
#           network, no hypothesis, deterministic seeds, CPU-only
#   slow:   pytest --runslow                              — compile sweeps,
#           long training runs; nightly / pre-release
#
# Tier-1 runs under a wall-clock budget (`timeout`) so the ROADMAP's
# <2-min dev-box target is enforced, not aspirational: TIER1_BUDGET
# (seconds, default 420 ≈ 2-min target + compile-cache-cold headroom;
# CI sets a wider budget for throttled 2-core runners). The slowest tests
# are printed (`--durations=10`) so regressions name themselves.
#
# The engine smoke also appends machine-readable benchmark records to
# BENCH_ci.json (see benchmarks/common.py emit()/BENCH_JSON); CI archives
# the file as an artifact to track the perf trajectory.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

TIER1_BUDGET="${TIER1_BUDGET:-420}"
echo "== tier-1: pytest -x -q (budget: ${TIER1_BUDGET}s) =="
tier1_start=$SECONDS
timeout "${TIER1_BUDGET}" python -m pytest -x -q --durations=10 || {
  code=$?
  if [[ $code -eq 124 ]]; then
    echo "FAIL: tier-1 exceeded the ${TIER1_BUDGET}s wall-clock budget" >&2
    echo "(move compile-heavy cases to @pytest.mark.slow — see ROADMAP.md)" >&2
  fi
  exit "$code"
}
tier1_s=$((SECONDS - tier1_start))
tier1_pct=$((100 * tier1_s / TIER1_BUDGET))
echo "tier-1 wall clock: ${tier1_s}s of ${TIER1_BUDGET}s budget (${tier1_pct}%)"
# surface actual-vs-budget where reviewers look (the Actions job summary),
# so creep toward the timeout is visible long before it starts failing runs
if [[ -n "${GITHUB_STEP_SUMMARY:-}" ]]; then
  {
    echo "### tier-1 wall clock"
    echo ""
    echo "| actual | budget (TIER1_BUDGET) | used |"
    echo "| --- | --- | --- |"
    echo "| ${tier1_s}s | ${TIER1_BUDGET}s | ${tier1_pct}% |"
  } >> "$GITHUB_STEP_SUMMARY"
fi

if [[ "${1:-}" == "--slow" ]]; then
  echo "== slow tier: pytest --runslow =="
  python -m pytest -q --runslow -m slow
fi

echo "== smoke: compiled simulation engine benchmark (dry run) =="
# force 16 host devices so both the per-shard-count records
# (shards={1,2,4,8}) and the cross-pod grid (pods×shards up to 4×2) land
# in BENCH_ci.json even on a single-accelerator box
rm -f BENCH_ci.json
XLA_FLAGS="--xla_force_host_platform_device_count=16${XLA_FLAGS:+ $XLA_FLAGS}" \
  BENCH_JSON=BENCH_ci.json PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
  python benchmarks/bench_sim_engine.py --dry-run
test -s BENCH_ci.json || { echo "FAIL: BENCH_ci.json not written" >&2; exit 1; }
# the local-SGD hot path must leave a per-PR trace: the client-step
# microbench record (µs per client step) is how cell-path regressions show
# up without waiting for the nightly cohort sweep
grep -q "client_step/local_sgd" BENCH_ci.json || {
  echo "FAIL: client-step microbench record missing from BENCH_ci.json" >&2
  exit 1
}
# the cross-pod reduction must leave a per-PR trace too: a pods=2 record
# proves the 2-D (pod, data) engine path actually ran in the smoke
grep -q "sim_engine/pods/.*pods=2" BENCH_ci.json || {
  echo "FAIL: sim_engine pods=2 record missing from BENCH_ci.json" >&2
  exit 1
}
# the streamed population backend must leave a per-PR trace: a
# backend=streamed record proves the host-resident-corpus round loop
# (sample → host gather → device_put → compute) actually ran in the smoke
grep -q "sim_engine/population/.*backend=streamed" BENCH_ci.json || {
  echo "FAIL: sim_engine population backend=streamed record missing" \
       "from BENCH_ci.json" >&2
  exit 1
}
# the mesh-sharded cohort sampler must leave a per-PR trace: a
# sampler=sharded population record proves the block-local Gumbel top-k
# path (block-keyed draws → per-shard top-k → canonical merge → O(cohort)
# masked scatters) actually ran in the smoke
grep -q "sim_engine/population/.*sampler=sharded" BENCH_ci.json || {
  echo "FAIL: sim_engine population sampler=sharded record missing" \
       "from BENCH_ci.json" >&2
  exit 1
}
# the production fault protocol must leave a per-PR trace: a faults record
# proves the over-selection/report-goal round path (fault fates → masked
# fold → commit/abort cond) actually ran in the smoke
grep -q "sim_engine/faults/" BENCH_ci.json || {
  echo "FAIL: sim_engine faults record missing from BENCH_ci.json" >&2
  exit 1
}

echo "== smoke: continuous-batching serving benchmark (dry run) =="
BENCH_JSON=BENCH_ci.json PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
  python benchmarks/bench_serve.py --dry-run
# the serving frontend must leave a per-PR trace: closed-loop latency/QPS
# records (>=3 concurrency levels) + the checkpoint hot-swap drill with
# zero dropped sessions
grep -q "serve/latency/concurrency=" BENCH_ci.json || {
  echo "FAIL: serve latency records missing from BENCH_ci.json" >&2
  exit 1
}
grep -q "serve/hot_swap/.*dropped=0" BENCH_ci.json || {
  echo "FAIL: serve hot-swap drill record (dropped=0) missing" \
       "from BENCH_ci.json" >&2
  exit 1
}
echo "BENCH_ci.json records:"
cat BENCH_ci.json

echo "CI OK"
