"""The paper's technique is architecture-agnostic: run one DP-FedAvg round
on a reduced variant of EVERY assigned architecture — dense, MoE, SSM,
hybrid, VLM, audio — through the same Algorithm-1 machinery.

    PYTHONPATH=src python examples/multi_arch_training.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, ClientConfig, DPConfig, get_config
from repro.core.dp_fedavg import finalize_round, server_step
from repro.core.server_optim import init_state
from repro.fl.client import user_update
from repro.models import build

dp = DPConfig(clients_per_round=4, noise_multiplier=0.3, clip_norm=0.5)
client = ClientConfig(local_epochs=1, batch_size=2, lr=0.1)
key = jax.random.PRNGKey(0)

print(f"{'arch':24s} {'family':8s} {'loss':>8s} {'|delta|':>9s} "
      f"{'clipped':>8s} {'|noise_std|':>11s}")
for arch in ASSIGNED_ARCHS:
    cfg = get_config(arch).reduced()
    model = build(cfg)
    params = model.init(key)
    opt_state = init_state(params)
    B, S = 2, 16

    def batches(uk):
        kt = jax.random.fold_in(key, uk)
        toks = jax.random.randint(kt, (1, B, S + 1), 0, cfg.vocab)
        b = {"tokens": toks[:, :, :-1], "labels": toks[:, :, 1:]}
        if cfg.family == "encdec":
            b["frames"] = jnp.zeros((1, B, cfg.n_audio_frames, cfg.d_model))
        if cfg.family == "vlm":
            b["image_embeds"] = jnp.zeros((1, B, cfg.n_image_tokens,
                                           cfg.d_model))
        return b

    # 4 clients run UserUpdate; the server aggregates per Algorithm 1
    total, norms, clipped, losses = None, [], [], []
    for u in range(4):
        delta, norm, was_clipped, loss = user_update(model, params,
                                                     batches(u), client, dp)
        total = delta if total is None else jax.tree_util.tree_map(
            jnp.add, total, delta)
        norms.append(float(norm)); clipped.append(float(was_clipped))
        losses.append(float(loss))
    noised, stats = finalize_round(total, 4, jax.random.fold_in(key, 99), dp)
    params, opt_state = server_step(params, opt_state, noised, dp)
    dn = float(jnp.sqrt(sum(jnp.sum(jnp.square(l))
                            for l in jax.tree_util.tree_leaves(noised))))
    print(f"{arch:24s} {cfg.family:8s} {np.mean(losses):8.3f} {dn:9.4f} "
          f"{np.mean(clipped):8.2f} {float(stats.noise_std):11.2e}")
print("\nevery family above went through clip -> average -> noise -> "
      "momentum unchanged (DESIGN.md §Arch-applicability).")
