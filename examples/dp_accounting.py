"""Privacy accounting walkthrough (paper §V-A, Table 5): reproduce the
hypothetical (ε, δ) bounds and explore the noise/participation tradeoff.

    PYTHONPATH=src python examples/dp_accounting.py
"""
from repro.core.accountant import MomentsAccountant, table5_epsilon

print("Table 5 (T=2000, qN=20000, z=0.8, delta=N^-1.1):")
print(f"{'N':>5s} {'paper':>7s} {'ours(WOR)':>10s} {'ours(Poisson)':>14s}")
paper = {2_000_000: 9.86, 3_000_000: 6.73, 4_000_000: 5.36,
         5_000_000: 4.54, 10_000_000: 3.27}
for N, ep in paper.items():
    wor = table5_epsilon(N, sampling="wor")
    poi = table5_epsilon(N, sampling="poisson")
    print(f"{N//10**6:4d}M {ep:7.2f} {wor:10.2f} {poi:14.2f}")

print("\nWhy the paper adds sigma=3.2e-5 of noise:")
print("  sigma = z*S/(qN) = 0.8*0.8/20000 =", 0.8 * 0.8 / 20000)

print("\nnoise multiplier sweep at N=4M (what z buys you):")
for z in (0.4, 0.8, 1.6, 3.2):
    acc = MomentsAccountant(q=20000 / 4e6, noise_multiplier=z, sampling="wor")
    acc.step(2000)
    print(f"  z={z:0.1f}  eps={acc.get_epsilon(4e6 ** -1.1):8.2f}")

print("\nclients-per-round sweep at N=4M, z=0.8 (amplification):")
for qn in (5_000, 20_000, 80_000):
    acc = MomentsAccountant(q=qn / 4e6, noise_multiplier=0.8, sampling="wor")
    acc.step(2000)
    print(f"  qN={qn:6d}  eps={acc.get_epsilon(4e6 ** -1.1):8.2f}  "
          f"(but sigma={0.8 * 0.8 / qn:.2e} shrinks too)")
