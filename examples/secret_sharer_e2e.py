"""End-to-end Federated Secret Sharer measurement (paper §IV, Table 4),
reduced scale: inject canary-carrying synthetic devices into the training
population, train with DP-FedAvg, then measure unintended memorization via
Random-Sampling rank and Beam Search.

    PYTHONPATH=src python examples/secret_sharer_e2e.py
"""
import jax

from repro.configs import ClientConfig, DPConfig, get_config
from repro.core.secret_sharer import (canary_eval_fn, canary_extracted,
                                      make_canaries, random_sampling_rank)
from repro.data.corpus import BigramCorpus
from repro.data.federated import FederatedDataset
from repro.fl.round import FederatedTrainer
from repro.models import build

VOCAB = 1000
GRID = [(1, 1), (4, 20), (16, 20)]   # reduced (n_u, n_e) grid

cfg = get_config("gboard-cifg-lstm").with_(vocab=VOCAB, d_model=64, d_ff=128)
model = build(cfg)
corpus = BigramCorpus(vocab_size=VOCAB, seed=0)
dataset = FederatedDataset(corpus, n_users=250, seq_len=16,
                           sentences_per_user=30)

canaries = make_canaries(jax.random.PRNGKey(42), vocab=VOCAB, grid=GRID,
                         per_config=1)
synth = dataset.inject_canaries(canaries)
print(f"population: {len(dataset.users)} devices "
      f"({len(synth)} secret-sharing synthetic devices)")

dp = DPConfig(clients_per_round=40, noise_multiplier=0.3, clip_norm=0.8,
              server_opt="momentum", server_lr=0.5, server_momentum=0.9)
client = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
# compiled engine backend with the in-scan canary hook: the
# memorization-vs-round curve is recorded while training
trainer = FederatedTrainer(model, dataset, dp, client, n_local_batches=3,
                           backend="engine", rounds_per_call=20,
                           eval_fn=canary_eval_fn(model, canaries),
                           eval_every=20)
print("training 80 rounds with canary devices in the population ...")
trainer.train(80, log_every=20)

ev = trainer.eval_history
for r, row in zip(ev["round"][ev["mask"]],
                  ev["values"]["canary_logppl"][ev["mask"]]):
    lps = "  ".join(f"{v:6.2f}" for v in row)
    print(f"  round {int(r):3d}  canary -log P(s|p): {lps}")

print("\n(n_u, n_e) -> RS rank (of 10k) | beam-extracted?   [paper Table 4]")
for c in canaries:
    rank = random_sampling_rank(model, trainer.state.params, c,
                                jax.random.PRNGKey(7), n_samples=10_000,
                                batch_size=2048)
    bs = canary_extracted(model, trainer.state.params, c)
    print(f"  ({c.n_u:2d},{c.n_e:3d})  rank={rank:6d}   "
          f"extracted={'YES' if bs else 'no '}")
print("\nexpected: (1,1) far from memorized; (16,20) memorized (rank→0).")
