"""Quickstart: train the paper's production NWP model (CIFG-LSTM) with
DP-FedAvg (Algorithm 1) on a simulated device fleet, track the privacy
accountant, and decode a few next-word predictions.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import ClientConfig, DPConfig, get_config
from repro.data.corpus import BigramCorpus
from repro.data.federated import FederatedDataset, held_out_batch
from repro.data.tokenizer import BOS
from repro.fl.population import PopulationSim
from repro.fl.round import FederatedTrainer
from repro.launch.serve import generate
from repro.models import build
from repro.models.layers import lm_loss

VOCAB = 2000

# 1. the paper's model (scaled for CPU): 1-layer CIFG-LSTM, tied embeddings
cfg = get_config("gboard-cifg-lstm").with_(vocab=VOCAB, d_model=64, d_ff=128)
model = build(cfg)

# 2. a federated population holding a synthetic Spanish-like corpus
corpus = BigramCorpus(vocab_size=VOCAB, seed=0)
dataset = FederatedDataset(corpus, n_users=300, seq_len=16,
                           sentences_per_user=30)

# 3. DP-FedAvg, Algorithm 1: clip S=0.8, fixed-size rounds, server momentum.
#    backend="engine" runs the whole simulation on device, 15 rounds per jit
#    call (see repro/fl/engine.py); backend="host" is the reference loop.
dp = DPConfig(clients_per_round=40, noise_multiplier=0.3, clip_norm=0.8,
              server_opt="momentum", server_lr=0.5, server_momentum=0.9)
client = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)

pop = PopulationSim(len(dataset.users), availability=0.3, seed=0)
trainer = FederatedTrainer(model, dataset, dp, client, pop=pop,
                           n_local_batches=3, backend="engine",
                           rounds_per_call=15)
print("training 60 DP-FedAvg rounds (compiled engine) ...")
trainer.train(60, log_every=15)

# 4. held-out quality + the moments accountant
hb = held_out_batch(corpus, 256, 16)
logits = model.forward(trainer.state.params,
                       {"tokens": jnp.asarray(hb["tokens"])})
loss = lm_loss(logits, jnp.asarray(hb["labels"]), cfg.vocab,
               jnp.asarray(hb["mask"]))
print(f"\nheld-out loss: {float(loss):.3f}  "
      f"(uniform would be {jnp.log(VOCAB):.3f})")
print(f"accountant: eps={trainer.accountant.get_epsilon(1e-6):.2f} "
      f"at delta=1e-6 after {trainer.accountant.rounds} rounds")

# 5. serve: batched next-word prediction with the recurrent cache
prompts = jnp.asarray([[BOS, 10, 11], [BOS, 20, 21]], jnp.int32)
out = generate(model, trainer.state.params, prompts, steps=5)
print("\ngreedy continuations:")
for row in out:
    print("  ", row.tolist())
