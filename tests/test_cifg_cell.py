"""PR-5 time-fused CIFG client step: Pallas cell kernels vs the jnp
reference (forward AND gradient), the whole-sequence time-fused VJP vs
plain autodiff through the scan, old-vs-new param-layout equivalence for
forward/prefill/decode, the remat knob, and the checkpoint migration shim.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.cifg_cell import cifg_cell_ref, cifg_sequence, cifg_step
from repro.kernels.cifg_cell import cifg_cell as K
from repro.models import build
from repro.train import checkpoint

KEY = jax.random.PRNGKey(11)


def _cell_inputs(B, H, scale=0.3):
    ks = jax.random.split(KEY, 4)
    return (jax.random.normal(ks[0], (B, 3 * H)),
            jax.random.normal(ks[1], (B, H)) * scale,
            jax.random.normal(ks[2], (B, H)) * scale,
            jax.random.normal(ks[3], (H, 3 * H)) * 0.2)


# ----------------------------- fused cell step ------------------------------


# tier-1 keeps one doubly-unaligned shape; the rest of the padding sweep
# runs in the slow tier (--runslow) to hold `pytest -x -q` under budget
@pytest.mark.parametrize("B,H", [
    pytest.param(2, 8, marks=pytest.mark.slow),
    (5, 48),
    pytest.param(8, 128, marks=pytest.mark.slow),
    pytest.param(3, 200, marks=pytest.mark.slow),
])
def test_cell_step_matches_ref(B, H):
    """Fused (padded, Pallas) step == jnp reference, forward and gradient,
    across unaligned B/H (the op pads to the (8, 128) tile grid)."""
    zx, h, c, wh = _cell_inputs(B, H)
    hn, cn = cifg_step(zx, h, c, wh)
    hr, cr = cifg_cell_ref(zx, h, c, wh)
    np.testing.assert_allclose(np.asarray(hn), np.asarray(hr),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(cn), np.asarray(cr),
                               rtol=1e-5, atol=1e-6)

    def loss(step_fn, args):
        hn, cn = step_fn(*args)
        return jnp.sum(jnp.sin(hn) * jnp.cos(cn))

    gf = jax.grad(lambda *a: loss(cifg_step, a), argnums=(0, 1, 2, 3))(
        zx, h, c, wh)
    gr = jax.grad(lambda *a: loss(cifg_cell_ref, a), argnums=(0, 1, 2, 3))(
        zx, h, c, wh)
    for a, b, name in zip(gf, gr, ("zx", "h", "c", "w_h")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6, err_msg=name)


def test_cell_step_bf16_compute():
    """compute_dtype="bfloat16" runs the matmuls in bf16 on both paths —
    results agree at bf16 tolerance."""
    zx, h, c, wh = _cell_inputs(6, 32)
    hn, cn = cifg_step(zx, h, c, wh, compute_dtype="bfloat16")
    hr, cr = cifg_cell_ref(zx, h, c, wh, compute_dtype=jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(hn), np.asarray(hr),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(cn), np.asarray(cr),
                               rtol=2e-2, atol=2e-2)


def test_cell_step_vmap_matches_ref():
    """The op and its VJP batch under vmap — the engine vmaps the client
    chunk axis over the whole loss gradient."""
    B, H, C = 4, 24, 7
    _, h, c, wh = _cell_inputs(B, H)
    zxs = jax.random.normal(jax.random.fold_in(KEY, 9), (C, B, 3 * H))

    vf = jax.vmap(lambda z: cifg_step(z, h, c, wh))(zxs)
    vr = jax.vmap(lambda z: cifg_cell_ref(z, h, c, wh))(zxs)
    np.testing.assert_allclose(np.asarray(vf[0]), np.asarray(vr[0]),
                               rtol=1e-5, atol=1e-6)
    gf = jax.grad(lambda w: jnp.sum(
        jax.vmap(lambda z: cifg_step(z, h, c, w)[0])(zxs)))(wh)
    gr = jax.grad(lambda w: jnp.sum(
        jax.vmap(lambda z: cifg_cell_ref(z, h, c, w)[0])(zxs)))(wh)
    np.testing.assert_allclose(np.asarray(gf), np.asarray(gr),
                               rtol=1e-4, atol=1e-6)


def test_cell_step_rejects_bad_shapes():
    zx, h, c, wh = _cell_inputs(4, 16)
    with pytest.raises(ValueError, match="expected zx"):
        cifg_step(zx[:, :-1], h, c, wh)
    with pytest.raises(ValueError, match="expected zx"):
        cifg_step(zx, h, c, wh[:-1])


def test_kernels_reject_untiled_shapes():
    """Direct kernel entry points demand the packed (8, 128)-tiled layout —
    ragged operands fail loudly at trace time (`ops` is the padding path)."""
    B, H = K.SUBLANES, K.LANES
    good = (jnp.zeros((3, B, H)), jnp.zeros((3, H, H)),
            jnp.zeros((B, H)), jnp.zeros((B, H)))
    for bad_idx, bad in ((2, jnp.zeros((B + 1, H))),      # ragged sublane
                         (2, jnp.zeros((B, H - 1))),      # ragged lane
                         (0, jnp.zeros((2, B, H)))):      # missing gate dim
        args = list(good)
        args[bad_idx] = bad
        with pytest.raises(ValueError, match="packed gate layout"):
            K.cell_fwd(*args)
    with pytest.raises(ValueError, match="cotangents"):
        K.cell_bwd(*good, jnp.zeros((B + 8, H)), jnp.zeros((B, H)))


def test_interpret_autoselect():
    """interpret=None auto-selects per backend (same policy as dp_clip):
    interpreter off-TPU, and the auto choice matches forcing it."""
    assert K.default_interpret() == (jax.default_backend() != "tpu")
    zx, h, c, wh = _cell_inputs(4, 16)
    auto = cifg_step(zx, h, c, wh)
    forced = cifg_step(zx, h, c, wh, interpret=K.default_interpret())
    np.testing.assert_array_equal(np.asarray(auto[0]), np.asarray(forced[0]))


# ----------------------------- time-fused sequence --------------------------


def _autodiff_seq(zx, h0, c0, wh):
    """Oracle: plain lax.scan over the jnp cell, ordinary jax autodiff."""
    def step(carry, zx_t):
        h, c = cifg_cell_ref(zx_t, carry[0], carry[1], wh)
        return (h, c), h
    (hf, cf), hs = jax.lax.scan(step, (h0, c0), zx)
    return hs, (hf, cf)


@pytest.mark.parametrize("cell", ["seq", "fused"])
@pytest.mark.parametrize("remat", [False, True])
def test_sequence_matches_autodiff(cell, remat):
    """The whole-sequence op (time-fused custom VJP; gate recompute and
    dw_h hoisted out of the reverse scan) reproduces plain autodiff through
    the scan — forward bit-comparable for "seq", gradient allclose for
    every input, with and without remat."""
    S, B, H = 7, 5, 24
    ks = jax.random.split(KEY, 4)
    zx = jax.random.normal(ks[0], (S, B, 3 * H))
    h0 = jax.random.normal(ks[1], (B, H)) * 0.3
    c0 = jax.random.normal(ks[2], (B, H)) * 0.3
    wh = jax.random.normal(ks[3], (H, 3 * H)) * 0.2

    hs, (hf, cf) = cifg_sequence(zx, h0, c0, wh, cell=cell, remat=remat)
    hr, (hrf, crf) = _autodiff_seq(zx, h0, c0, wh)
    if cell == "seq":
        np.testing.assert_array_equal(np.asarray(hs), np.asarray(hr))
    else:
        np.testing.assert_allclose(np.asarray(hs), np.asarray(hr),
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(cf), np.asarray(crf),
                               rtol=1e-5, atol=1e-6)

    def loss(seq_fn, zx, h0, c0, wh):
        hs, (hf, cf) = seq_fn(zx, h0, c0, wh)
        return jnp.sum(jnp.sin(hs)) + jnp.sum(jnp.cos(hf) * cf)

    gf = jax.grad(
        lambda *a: loss(lambda *b: cifg_sequence(*b, cell=cell, remat=remat),
                        *a), argnums=(0, 1, 2, 3))(zx, h0, c0, wh)
    gr = jax.grad(lambda *a: loss(_autodiff_seq, *a),
                  argnums=(0, 1, 2, 3))(zx, h0, c0, wh)
    for a, b, name in zip(gf, gr, ("zx", "h0", "c0", "w_h")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-5,
                                   err_msg=f"{cell}/remat={remat}/{name}")


def test_sequence_grad_matches_autodiff_bf16():
    """bf16 gradient envelope: the time-fused backward recomputes the
    gates through the same f32-accumulated GEMM as the forward cell
    (`preferred_element_type`), so its deviation from plain bf16 autodiff
    stays within the f32-cotangent-policy envelope (~bf16 epsilon), not a
    shifted linearization point on top of it."""
    S, B, H = 6, 4, 16
    ks = jax.random.split(KEY, 2)
    zx = jax.random.normal(ks[0], (S, B, 3 * H))
    wh = jax.random.normal(ks[1], (H, 3 * H)) * 0.2
    z = jnp.zeros((B, H))

    def loss(seq_fn, wh):
        hs, _ = seq_fn(wh)
        return jnp.sum(hs * hs)

    gf = jax.grad(lambda w: loss(
        lambda w: cifg_sequence(zx, z, z, w, cell="seq",
                                compute_dtype="bfloat16"), w))(wh)

    def ref_bf16(w):
        def step(carry, zx_t):
            h, c = cifg_cell_ref(zx_t, carry[0], carry[1], w,
                                 compute_dtype=jnp.bfloat16)
            return (h, c), h
        (hf, cf), hs = jax.lax.scan(step, (z, z), zx)
        return hs, (hf, cf)

    gr = jax.grad(lambda w: loss(ref_bf16, w))(wh)
    # f32 cotangents (by design) still differ from bf16 autodiff at the
    # bf16-epsilon level; the regression (bf16-rounded recompute) was an
    # order of magnitude beyond this envelope
    np.testing.assert_allclose(np.asarray(gf), np.asarray(gr),
                               rtol=2e-2, atol=5e-4)


def test_sequence_remat_grads_bit_equal():
    """remat only changes *when* the state stacks are (re)computed, not the
    arithmetic — gradients must match bitwise."""
    S, B, H = 6, 4, 16
    ks = jax.random.split(KEY, 2)
    zx = jax.random.normal(ks[0], (S, B, 3 * H))
    wh = jax.random.normal(ks[1], (H, 3 * H)) * 0.2
    z = jnp.zeros((B, H))

    def loss(wh, remat):
        hs, _ = cifg_sequence(zx, z, z, wh, cell="seq", remat=remat)
        return jnp.sum(hs * hs)

    g0 = jax.jit(jax.grad(lambda w: loss(w, False)))(wh)
    g1 = jax.jit(jax.grad(lambda w: loss(w, True)))(wh)
    np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))


def test_sequence_rejects_bad_shapes():
    S, B, H = 4, 3, 8
    zx = jnp.zeros((S, B, 3 * H))
    z = jnp.zeros((B, H))
    wh = jnp.zeros((H, 3 * H))
    with pytest.raises(ValueError, match="cifg_sequence"):
        cifg_sequence(zx[:, :, :-1], z, z, wh)
    with pytest.raises(ValueError, match="cell must be"):
        cifg_sequence(zx, z, z, wh, cell="nope")


# ----------------------------- model-level paths ----------------------------


def _lstm_setup(cell_path="auto", compute_dtype="float32", d=12, h=20,
                vocab=64, B=3, S=10):
    cfg = get_config("gboard-cifg-lstm").with_(
        vocab=vocab, d_model=d, d_ff=h, cell_path=cell_path,
        compute_dtype=compute_dtype)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(3))
    tokens = jax.random.randint(jax.random.fold_in(KEY, 5), (B, S + 1), 0,
                                vocab)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    return cfg, model, params, batch


# tier-1 compares the resolved default against the autodiff reference
# ("auto" == "seq" on CPU); the explicit fused/seq model-level duplicates
# run in the slow tier — fused fwd/grad is already covered per-step and
# per-sequence above
@pytest.mark.parametrize("path", [
    "auto",
    pytest.param("fused", marks=pytest.mark.slow),
    pytest.param("seq", marks=pytest.mark.slow),
])
def test_model_cell_paths_agree(path):
    """loss + gradient agree across every cell_path on the same params —
    the knob changes the implementation, not the model."""
    cfg, model, params, batch = _lstm_setup(cell_path="ref")
    ref_loss, ref_grads = jax.value_and_grad(model.loss_fn)(params, batch)
    cfg, model, params, batch = _lstm_setup(cell_path=path)
    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5,
                               err_msg=path)
    for name in ("w_x", "w_h", "b_gates", "w_proj"):
        np.testing.assert_allclose(
            np.asarray(grads[name]), np.asarray(ref_grads[name]),
            rtol=1e-4, atol=1e-6, err_msg=f"{path}/{name}")
    np.testing.assert_allclose(
        np.asarray(grads["embed"]["tok"]),
        np.asarray(ref_grads["embed"]["tok"]),
        rtol=1e-4, atol=1e-6, err_msg=f"{path}/embed")


def test_model_remat_grad_allclose():
    """The wired remat knob: loss_fn(remat=True) gradients match the
    un-remat path (satellite — the kwarg used to be accepted but dead)."""
    for path in ("seq", "ref"):
        cfg, model, params, batch = _lstm_setup(cell_path=path)
        from repro.models.lstm import loss_fn
        g0 = jax.grad(lambda p: loss_fn(p, batch, cfg, remat=False))(params)
        g1 = jax.grad(lambda p: loss_fn(p, batch, cfg, remat=True))(params)
        for a, b in zip(jax.tree_util.tree_leaves(g0),
                        jax.tree_util.tree_leaves(g1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-7, err_msg=path)


# ------------------------- old-vs-new layout equivalence --------------------


def _old_layout_forward(params_old, batch, cfg, collect_cache=False):
    """The pre-split reference implementation: fused w_gates, concat inside
    the scan — the exact PR-4 compute graph, used as the oracle for the
    layout migration."""
    from repro.models.embed import embed_tokens, lm_logits
    cd = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    B, S = tokens.shape
    hidden = cfg.d_ff
    x = embed_tokens(params_old["embed"], tokens, cd)

    def cell(x_t, h, c):
        z = jnp.concatenate([x_t, h.astype(cd)], axis=-1) \
            @ params_old["w_gates"].astype(cd)
        z = z.astype(jnp.float32) + params_old["b_gates"]
        f = jax.nn.sigmoid(z[:, :hidden] + 1.0)
        o = jax.nn.sigmoid(z[:, hidden:2 * hidden])
        g = jnp.tanh(z[:, 2 * hidden:])
        c_new = f * c + (1.0 - f) * g
        return o * jnp.tanh(c_new), c_new

    def step(carry, x_t):
        h, c = cell(x_t, *carry)
        return (h, c), h

    zeros = jnp.zeros((B, hidden), jnp.float32)
    (hf, cf), hs = jax.lax.scan(step, (zeros, zeros), x.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2).astype(cd)
    logits = lm_logits(params_old["embed"],
                       hs @ params_old["w_proj"].astype(cd))
    return (logits, (hf, cf)) if collect_cache else logits


def _fuse_layout(params):
    out = dict(params)
    out["w_gates"] = jnp.concatenate([out.pop("w_x"), out.pop("w_h")],
                                     axis=0)
    return out


def test_forward_matches_old_layout():
    """Same weights, old fused layout vs new split layout: the hoisted
    input GEMM + split recurrent matmul reproduce the pre-split forward
    (f32 exact up to reassociation; bf16 at bf16 tolerance)."""
    for cdt, tol in (("float32", 1e-5), ("bfloat16", 3e-2)):
        cfg, model, params, batch = _lstm_setup(compute_dtype=cdt)
        new = model.forward(params, batch)
        old = _old_layout_forward(_fuse_layout(params), batch, cfg)
        np.testing.assert_allclose(np.asarray(new, np.float32),
                                   np.asarray(old, np.float32),
                                   rtol=tol, atol=tol)


def test_prefill_decode_match_old_layout():
    """decode_step/prefill on the split layout reproduce the old fused
    cell's serving path (satellite: serving gets the same param split)."""
    cfg, model, params, batch = _lstm_setup()
    old_params = _fuse_layout(params)
    logits_old, (h_old, c_old) = _old_layout_forward(
        old_params, batch, cfg, collect_cache=True)
    last, cache = model.prefill(params, {"tokens": batch["tokens"]})
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits_old[:, -1, :]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cache["h"]), np.asarray(h_old),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(cache["c"]), np.asarray(c_old),
                               rtol=1e-5, atol=1e-6)
    # one decode step == one more column of the old teacher-forced forward
    nxt = batch["labels"][:, -1]
    ext = jnp.concatenate([batch["tokens"], nxt[:, None]], axis=1)
    logits_ext = _old_layout_forward(old_params, {"tokens": ext}, cfg)
    step_logits, _ = model.decode_step(params, nxt, cache)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(logits_ext[:, -1, :]),
                               rtol=1e-5, atol=1e-5)


# ----------------------------- checkpoint migration -------------------------


def test_checkpoint_migration_roundtrip(tmp_path):
    """An old-layout checkpoint (fused w_gates) loads into the split layout
    through the one-shot shim, byte-preserving the weights; new-layout
    checkpoints round-trip untouched (idempotence)."""
    cfg, model, params, batch = _lstm_setup()
    old_params = _fuse_layout(params)
    path = tmp_path / "old_layout.msgpack"
    checkpoint.save(path, old_params, meta={"layout": "pre-split"})
    loaded, meta = checkpoint.load(path)
    assert meta["layout"] == "pre-split"
    assert "w_gates" not in loaded
    np.testing.assert_array_equal(loaded["w_x"], np.asarray(params["w_x"]))
    np.testing.assert_array_equal(loaded["w_h"], np.asarray(params["w_h"]))
    # the migrated tree drives the current model bit-identically
    loaded = jax.tree_util.tree_map(jnp.asarray, loaded)
    np.testing.assert_array_equal(
        np.asarray(model.forward(loaded, batch)),
        np.asarray(model.forward(params, batch)))

    # idempotent: a new-layout checkpoint passes through unchanged
    path2 = tmp_path / "new_layout.msgpack"
    checkpoint.save(path2, params)
    again, _ = checkpoint.load(path2)
    assert set(again) == set(params)
    np.testing.assert_array_equal(again["w_h"], np.asarray(params["w_h"]))


def test_migration_handles_nested_and_non_lstm_trees():
    from repro.train.checkpoint import migrate_lstm_gates
    wg = np.arange(5 * 6, dtype=np.float32).reshape(5, 6)  # d=3, h=2
    tree = {"model": {"w_gates": wg, "b_gates": np.zeros(6)},
            "opt": [{"w_gates": wg}, "keep"],
            "w_x": np.ones((2, 2))}  # top-level w_x: not an lstm block
    out = migrate_lstm_gates(tree)
    np.testing.assert_array_equal(out["model"]["w_x"], wg[:3])
    np.testing.assert_array_equal(out["model"]["w_h"], wg[3:])
    np.testing.assert_array_equal(out["opt"][0]["w_h"], wg[3:])
    assert out["opt"][1] == "keep"
    # a square-ish non-gate matrix (rows ≤ h) is left alone
    small = {"w_gates": np.zeros((2, 6))}
    assert "w_gates" in migrate_lstm_gates(small)
