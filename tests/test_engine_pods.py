"""Cross-pod (2-D ``(pod, data)``) engine ↔ 1-D / unsharded engine parity.

The multi-pod engine (`SimEngine(num_pods=P, num_shards=S)`) lays the
cohort out pod-major over the ``(pod, data)`` batch slice of the production
mesh and reduces hierarchically: per-shard canonical block partials gather
over the intra-pod ``data`` axis, fold pod-locally, and only the pod
partials cross the ``pod`` axis. Because the pod partials are internal
nodes of `fold_blocks`' balanced tree (`reduction.fold_pods`), every
topology whose ``num_pods × num_shards`` divides `CANON_BLOCKS` — and every
``cohort_chunk`` dividing the block size — must be *bit-identical* to the
unsharded engine, at zero noise and under σ>0. That bitwise invariance is
what keeps the clipped-sum sensitivity S/(qN), and hence the accountant's
ε, independent of how pods are laid out between launches.

Grid points above the visible device count are skipped; run the full
{pods 1, 2} × {shards 1, 2, 4} × {chunk | block} grid on CPU with

    XLA_FLAGS=--xla_force_host_platform_device_count=16 \
        PYTHONPATH=src python -m pytest -q tests/test_engine_pods.py

(the CI ``tier1-pods`` matrix leg does exactly this; the exhaustive
chunk × noise cross runs in the nightly ``--runslow`` leg).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ClientConfig, DPConfig, get_config
from repro.configs.base import MeshConfig
from repro.data.corpus import BigramCorpus
from repro.data.federated import FederatedDataset
from repro.fl.engine import SimEngine, canon_pad
from repro.fl.population import PopulationSim
from repro.fl.round import FederatedTrainer
from repro.models import build
from repro.sharding.specs import sim_mesh_config

VOCAB = 300
ROUNDS = 2           # = rounds_per_call → one compiled scan per engine
COHORT = 32          # padded 32 → 8 blocks → block size 4 → chunks {1,2,4}

def _needs(n):
    return pytest.mark.skipif(
        len(jax.devices()) < n,
        reason=f"needs {n} devices (XLA_FLAGS="
               f"--xla_force_host_platform_device_count=16)")


# (pods, shards) topologies whose total divides CANON_BLOCKS = 8 — the
# bit-parity family the acceptance grid covers
TOPOLOGIES = [(2, 1), (2, 2), (2, 4), (4, 2), (8, 1)]
topo_params = [pytest.param(p, s, marks=_needs(p * s))
               for p, s in TOPOLOGIES]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("gboard-cifg-lstm").with_(vocab=VOCAB, d_model=24,
                                               d_ff=48)
    model = build(cfg)
    corpus = BigramCorpus(vocab_size=VOCAB, seed=0)
    ds = FederatedDataset(corpus, n_users=80, seq_len=16,
                          sentences_per_user=20)
    return cfg, model, ds


@pytest.fixture(scope="module")
def runner(setup):
    """Memoized engine runs keyed by config — parity tests share runs."""
    _, model, ds = setup
    data = ds.to_device_arrays()
    cache = {}

    def run(*, pods=1, shards=1, chunk=None, noise=0.0, sampling="fixed",
            cohort=COHORT):
        key = (pods, shards, chunk, noise, sampling, cohort)
        if key not in cache:
            dp = DPConfig(clients_per_round=cohort, noise_multiplier=noise,
                          clip_norm=0.8, server_opt="momentum",
                          server_lr=0.5, server_momentum=0.9,
                          sampling=sampling)
            cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
            eng = SimEngine(
                model, data, dp, cl, n_local_batches=2,
                availability=1.0 if sampling == "poisson" else 0.6,
                rounds_per_call=2, num_pods=pods, num_shards=shards,
                cohort_chunk=chunk)
            state = eng.init_state(model.init(jax.random.PRNGKey(1)), seed=0)
            state, hist = eng.run(state, ROUNDS)
            cache[key] = (eng, state, hist)
        return cache[key]

    return run


def _max_leaf_diff(a, b):
    d = jax.tree_util.tree_map(
        lambda x, y: float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                           - y.astype(jnp.float32)))), a, b)
    return max(jax.tree_util.tree_leaves(d))


def _assert_bitwise(run_a, run_b):
    _, sa, ha = run_a
    _, sb, hb = run_b
    np.testing.assert_array_equal(ha["loss"], hb["loss"])
    np.testing.assert_array_equal(ha["mean_update_norm"],
                                  hb["mean_update_norm"])
    np.testing.assert_array_equal(ha["n_clients"], hb["n_clients"])
    np.testing.assert_array_equal(np.asarray(sa.participation),
                                  np.asarray(sb.participation))
    assert _max_leaf_diff(sa.params, sb.params) == 0.0
    assert _max_leaf_diff(sa.opt_state, sb.opt_state) == 0.0


# ------------------------------------------------ cross-pod parity (tier-1)


@pytest.mark.parametrize("pods,shards", topo_params)
def test_pod_trajectory_parity_bit_exact(runner, pods, shards):
    """Zero noise: laying the cohort out over pods must not move a single
    bit against the unsharded engine — the pod partials are internal nodes
    of the same canonical reduction tree."""
    eng, _, _ = runner(pods=pods, shards=shards)
    assert eng.total_shards == pods * shards
    assert eng.mesh is not None
    assert eng.mesh.axis_names == (("pod", "data") if pods > 1
                                   else ("data",))
    _assert_bitwise(runner(pods=pods, shards=shards), runner())


@pytest.mark.parametrize("pods,shards",
                         [pytest.param(2, 4, marks=_needs(8))])
def test_pod_parity_survives_noise(runner, pods, shards):
    """σ > 0: the Gaussian draw comes from the replicated PRNG stream
    (drawn once, after the cross-pod sum), so noised trajectories are
    pod-count-invariant — σ = zS/qN can't drift with the pod layout."""
    _assert_bitwise(runner(pods=pods, shards=shards, noise=0.3),
                    runner(noise=0.3))
    _, _, hist = runner(pods=pods, shards=shards, noise=0.3)
    np.testing.assert_allclose(hist["noise_std"], 0.3 * 0.8 / COHORT,
                               rtol=1e-6)


@pytest.mark.parametrize("pods,shards",
                         [pytest.param(2, 2, marks=_needs(4))])
def test_pod_poisson_parity(runner, pods, shards):
    """Poisson-sampled variable-size rounds shard across pods too: the
    (realized round size, trajectory) pair matches the unsharded engine
    exactly."""
    _assert_bitwise(runner(pods=pods, shards=shards, sampling="poisson"),
                    runner(sampling="poisson"))


@pytest.mark.parametrize("pods,shards,chunk",
                         [pytest.param(2, 2, 1, marks=_needs(4)),
                          pytest.param(2, 4, 2, marks=_needs(8))])
def test_pod_chunk_composition(runner, pods, shards, chunk):
    """The intra-block streaming fold stays per-pod: any (pods × shards
    dividing CANON_BLOCKS) × (chunk dividing the block size) grid point is
    bit-identical to the unsharded auto-chunk reference."""
    _assert_bitwise(runner(pods=pods, shards=shards, chunk=chunk), runner())


@pytest.mark.parametrize("pods,shards",
                         [pytest.param(2, 2, marks=_needs(4))])
def test_pod_ragged_cohort_pads_not_truncates(setup, runner, pods, shards):
    """cohort=10 divides neither the 4 total shards nor the 8-block grid —
    the buffer pads to the next canonical multiple and keeps all 10 devices
    in every round, on every pod."""
    eng, state, hist = runner(pods=pods, shards=shards, cohort=10)
    assert eng.padded == canon_pad(10, shards, pods) == 16
    assert eng.padded % (pods * shards) == 0
    np.testing.assert_array_equal(hist["n_clients"], 10)
    assert int(np.asarray(state.participation).sum()) == ROUNDS * 10
    _assert_bitwise(runner(pods=pods, shards=shards, cohort=10),
                    runner(cohort=10))


# --------------------------------------------------- exhaustive grid (slow)


@pytest.mark.slow
@pytest.mark.parametrize("noise", [0.0, 0.3])
@pytest.mark.parametrize("pods,shards,chunk", [
    pytest.param(p, s, c, marks=_needs(p * s))
    for p in (1, 2) for s in (1, 2, 4) for c in (1, 2, 4)
    if (p, s, c) != (1, 1, 4)      # the reference run itself
])
def test_full_pods_shards_chunk_grid(runner, pods, shards, chunk, noise):
    """Acceptance grid: bit-identical trajectories (zero-noise AND σ>0)
    across the full {pods 1, 2} × {shards 1, 2, 4} × {every cohort_chunk
    dividing the block size} cross on forced-16-device CPU."""
    _assert_bitwise(runner(pods=pods, shards=shards, chunk=chunk,
                           noise=noise),
                    runner(chunk=4, noise=noise))


# ------------------------------------------------------- plumbing / errors


@pytest.mark.parametrize("pods,shards",
                         [pytest.param(2, 2, marks=_needs(4))])
def test_trainer_pods_matches_unsharded(setup, pods, shards):
    """FederatedTrainer(backend="engine", num_pods=P) reproduces the
    unsharded trainer's history and participation exactly at zero noise."""
    _, model, ds = setup
    dp = DPConfig(clients_per_round=12, noise_multiplier=0.0, clip_norm=0.8,
                  server_opt="momentum", server_lr=0.5, server_momentum=0.9)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    runs = {}
    for p, s in ((1, 1), (pods, shards)):
        pop = PopulationSim(len(ds.users), availability=0.6, seed=0)
        tr = FederatedTrainer(model, ds, dp, cl, pop=pop, n_local_batches=2,
                              seed=0, backend="engine", rounds_per_call=2,
                              num_pods=p, num_shards=s)
        tr.train(2)
        runs[(p, s)] = tr
    a, b = runs[(1, 1)], runs[(pods, shards)]
    assert [r["loss"] for r in a.state.history] == \
        [r["loss"] for r in b.state.history]
    np.testing.assert_array_equal(a.participation, b.participation)
    assert a.accountant.rounds == b.accountant.rounds == 2


def test_trainer_rejects_pods_on_host_backend(setup):
    _, model, ds = setup
    dp = DPConfig(clients_per_round=12, noise_multiplier=0.0, clip_norm=0.8)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    with pytest.raises(ValueError, match="engine"):
        FederatedTrainer(model, ds, dp, cl, backend="host", num_pods=2)


def test_engine_mesh_config_entry_point(setup):
    """Passing sim_mesh_config(S, P) is equivalent to num_shards/num_pods —
    and a disagreeing explicit knob fails loudly instead of being silently
    overridden."""
    _, model, ds = setup
    data = ds.to_device_arrays()
    dp = DPConfig(clients_per_round=12, noise_multiplier=0.0, clip_norm=0.8)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    if len(jax.devices()) >= 4:
        eng = SimEngine(model, data, dp, cl, availability=0.6,
                        mesh_config=sim_mesh_config(2, 2))
        assert (eng.num_pods, eng.num_shards, eng.total_shards) == (2, 2, 4)
        assert eng.mesh.axis_names == ("pod", "data")
    with pytest.raises(ValueError, match="num_pods"):
        SimEngine(model, data, dp, cl, num_pods=4,
                  mesh_config=sim_mesh_config(1, 2))
    with pytest.raises(ValueError, match="num_shards"):
        SimEngine(model, data, dp, cl, num_shards=4,
                  mesh_config=sim_mesh_config(2, 2))


def test_insufficient_devices_for_pods_is_a_clear_error(setup):
    """num_pods × num_shards beyond the visible device count must fail at
    construction, naming the XLA_FLAGS escape hatch."""
    _, model, ds = setup
    dp = DPConfig(clients_per_round=12, noise_multiplier=0.0, clip_norm=0.8)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        SimEngine(model, ds.to_device_arrays(), dp, cl,
                  num_pods=len(jax.devices()) + 1, num_shards=1)


def test_pod_major_layout_is_the_production_layout():
    """The engine's cohort mesh config is exactly the batch slice of the
    production (pod, data, model) mesh: same axis names, same pod-major
    order — a sim-validated (pods, shards) point carries over."""
    from repro.configs.base import MULTI_POD
    cfg = sim_mesh_config(4, 2)
    assert cfg == MeshConfig((2, 4), ("pod", "data"))
    assert cfg.axes == MULTI_POD.axes[:2]
    assert sim_mesh_config(4, 1) == MeshConfig((4,), ("data",))
    for bad in ((0, 1), (1, 0), (-2, 2)):
        with pytest.raises(ValueError):
            sim_mesh_config(*bad)
