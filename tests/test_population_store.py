"""PopulationStore unit suite: round-trips, mmap format, edge guards.

The store contract is that every implementation serves values bit-identical
to the rows of ``FederatedDataset.to_device_arrays()`` — that is what makes
the streamed engine backend's trajectories bit-exact (see
tests/test_engine_streamed.py for the engine-level parity grid). This file
covers the store layer itself plus the empty-shard / ``max_examples=0``
dataset guards fixed alongside it.
"""
import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.data.corpus import BigramCorpus
from repro.data.federated import (FederatedDataset, sentences_to_examples)
from repro.data.population_store import (InMemoryPopulationStore,
                                         MmapPopulationStore,
                                         PopulationStore,
                                         ReplicatedPopulationStore,
                                         STORE_META, as_population_store,
                                         write_population_store)

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def dataset():
    corpus = BigramCorpus(vocab_size=300, seed=0)
    return FederatedDataset(corpus, n_users=40, seq_len=16,
                            sentences_per_user=20)


@pytest.fixture(scope="module")
def store(dataset):
    return InMemoryPopulationStore.from_dataset(dataset)


# ----------------------------------------------------- dataset edge guards

def test_max_examples_zero_is_a_real_cap():
    # regression: `if max_examples and ...` treated an explicit 0 as "no cap"
    ex = sentences_to_examples([[1, 2, 3], [4, 5]], seq_len=4, max_examples=0)
    assert ex.shape == (0, 5)
    assert ex.dtype == np.int32


def test_max_examples_caps_before_append():
    ex = sentences_to_examples([[1, 2]] * 7, seq_len=4, max_examples=3)
    assert ex.shape == (3, 5)


def test_max_examples_negative_raises():
    with pytest.raises(ValueError, match="max_examples"):
        sentences_to_examples([[1, 2]], seq_len=4, max_examples=-1)


def test_to_device_arrays_rejects_empty_shard():
    corpus = BigramCorpus(vocab_size=300, seed=1)
    ds = FederatedDataset(corpus, n_users=4, seq_len=16,
                          sentences_per_user=5)
    ds.users[2].examples = np.zeros((0, 17), np.int32)
    with pytest.raises(ValueError, match="zero examples"):
        ds.to_device_arrays()


def test_user_tensor_rejects_empty_shard():
    corpus = BigramCorpus(vocab_size=300, seed=1)
    ds = FederatedDataset(corpus, n_users=2, seq_len=16,
                          sentences_per_user=5)
    ds.users[0].examples = np.zeros((0, 17), np.int32)
    with pytest.raises(ValueError, match="zero examples"):
        ds.user_tensor(0, 4, 2, np.random.default_rng(0))


# ------------------------------------------------------------ in-memory

def test_in_memory_round_trip(dataset, store):
    data = dataset.to_device_arrays()
    out = store.device_arrays()
    for k in ("examples", "counts", "synthetic"):
        np.testing.assert_array_equal(out[k], data[k])
    assert store.n_users == dataset.n_users
    assert store.row_len == dataset.seq_len + 1


def test_gather_matches_fancy_indexing(store):
    ids = np.array([3, 3, 0, 39, 17])  # duplicates + extremes are fine
    np.testing.assert_array_equal(store.gather(ids), store.examples[ids])
    np.testing.assert_array_equal(store.gather_counts(ids),
                                  store.counts[ids])


def test_gather_out_of_range_raises(store):
    with pytest.raises(IndexError, match="out of range"):
        store.gather([0, store.n_users])
    with pytest.raises(IndexError, match="out of range"):
        store.gather([-1])


def test_store_rejects_empty_user():
    ex = np.ones((3, 2, 5), np.int32)
    counts = np.array([2, 0, 1], np.int32)
    with pytest.raises(ValueError, match="no examples"):
        InMemoryPopulationStore(ex, counts, np.zeros(3, bool))


def test_store_rejects_shape_mismatch():
    ex = np.ones((3, 2, 5), np.int32)
    with pytest.raises(ValueError, match="must both"):
        InMemoryPopulationStore(ex, np.ones(2, np.int32),
                                np.zeros(3, bool))
    with pytest.raises(ValueError, match="examples must be"):
        InMemoryPopulationStore(ex[:, :, 0], np.ones(3, np.int32),
                                np.zeros(3, bool))


# ------------------------------------------------------------ mmap format

def test_mmap_round_trip(store, tmp_path):
    # shard size deliberately not dividing n_users: last shard is ragged
    path = write_population_store(tmp_path / "pop", store, shard_users=17)
    back = MmapPopulationStore(path)
    assert (back.n_users, back.emax, back.row_len) == (
        store.n_users, store.emax, store.row_len)
    assert back.n_shards == -(-store.n_users // 17)
    np.testing.assert_array_equal(back.counts, store.counts)
    np.testing.assert_array_equal(back.synthetic, store.synthetic)
    # cross-shard gather in arbitrary order with duplicates
    ids = np.array([39, 0, 17, 17, 22, 5])
    np.testing.assert_array_equal(back.gather(ids), store.gather(ids))
    np.testing.assert_array_equal(back.device_arrays()["examples"],
                                  store.device_arrays()["examples"])


def test_mmap_shards_open_lazily(store, tmp_path):
    path = write_population_store(tmp_path / "pop", store, shard_users=10)
    back = MmapPopulationStore(path)
    assert back._shards == {}
    back.gather([0, 35])               # touches shards 0 and 3 only
    assert sorted(back._shards) == [0, 3]
    assert isinstance(back._shard(0), np.memmap)


def test_mmap_meta_validation(store, tmp_path):
    with pytest.raises(FileNotFoundError, match=STORE_META):
        MmapPopulationStore(tmp_path / "nowhere")
    path = write_population_store(tmp_path / "pop", store, shard_users=10)
    meta = json.loads((path / STORE_META).read_text())
    meta["version"] = 99
    (path / STORE_META).write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="version"):
        MmapPopulationStore(path)
    meta["version"] = 1
    meta["n_shards"] = 2
    (path / STORE_META).write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="corrupt"):
        MmapPopulationStore(path)


def test_write_store_rejects_bad_shard_users(store, tmp_path):
    with pytest.raises(ValueError, match="shard_users"):
        write_population_store(tmp_path / "pop", store, shard_users=0)


# ------------------------------------------------------------ replicated

def test_replicated_view(store):
    rep = ReplicatedPopulationStore(store, 130)
    assert rep.n_users == 130
    assert rep.counts.shape == (130,)
    ids = np.array([0, 40, 80, 129, 41])
    np.testing.assert_array_equal(rep.gather(ids),
                                  store.gather(ids % store.n_users))
    np.testing.assert_array_equal(rep.gather_counts(ids),
                                  store.counts[ids % store.n_users])
    with pytest.raises(IndexError):
        rep.gather([130])
    with pytest.raises(ValueError, match="n_users"):
        ReplicatedPopulationStore(store, store.n_users - 1)


# ------------------------------------------------------------ normalization

def test_as_population_store(store, dataset, tmp_path):
    assert as_population_store(store) is store
    wrapped = as_population_store(dataset.to_device_arrays())
    assert isinstance(wrapped, InMemoryPopulationStore)
    path = write_population_store(tmp_path / "pop", store, shard_users=10)
    opened = as_population_store(str(path))
    assert isinstance(opened, MmapPopulationStore)
    assert opened.n_users == store.n_users
    with pytest.raises(TypeError, match="PopulationStore"):
        as_population_store(42)


def test_base_class_gather_abstract(store):
    with pytest.raises(NotImplementedError):
        PopulationStore.gather(store, [0])


# ------------------------------------------------------------ converter CLI

@pytest.mark.slow
def test_build_corpus_cli_round_trip(tmp_path):
    """tools/build_corpus.py writes a store bit-identical to the equivalent
    FederatedDataset (same generator, same seeds)."""
    out = tmp_path / "pop_cli"
    r = subprocess.run(
        [sys.executable, str(REPO / "tools" / "build_corpus.py"),
         "--out", str(out), "--n-users", "30", "--vocab", "300",
         "--seq-len", "16", "--sentences-per-user", "20",
         "--shard-users", "13"],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    back = MmapPopulationStore(out)
    corpus = BigramCorpus(vocab_size=300, seed=0)
    ds = FederatedDataset(corpus, n_users=30, seq_len=16,
                          sentences_per_user=20)
    data = ds.to_device_arrays()
    np.testing.assert_array_equal(back.device_arrays()["examples"],
                                  data["examples"])
    np.testing.assert_array_equal(back.counts, data["counts"])


@pytest.mark.slow
def test_build_corpus_cli_replicate(tmp_path):
    out = tmp_path / "pop_rep"
    r = subprocess.run(
        [sys.executable, str(REPO / "tools" / "build_corpus.py"),
         "--out", str(out), "--n-users", "20", "--vocab", "300",
         "--seq-len", "16", "--sentences-per-user", "10",
         "--replicate", "95", "--shard-users", "32"],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    back = MmapPopulationStore(out)
    assert back.n_users == 95
    np.testing.assert_array_equal(back.gather([0])[0], back.gather([20])[0])
