"""Pallas kernel sweeps: shapes × dtypes, allclose vs pure-jnp oracles
(interpret=True executes the kernel bodies on CPU; TPU is the target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dp_clip.ops import clip_accumulate, fused_sumsq
from repro.kernels.dp_clip.ref import clip_factor_ref, sumsq_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref

KEY = jax.random.PRNGKey(7)


# ----------------------------- dp_clip --------------------------------------


@pytest.mark.parametrize("shape", [(5,), (1000, 37), (256, 128), (3, 7, 11)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sumsq_sweep(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    tree = {"x": x}
    got = float(fused_sumsq(tree))
    want = float(sumsq_ref(x))
    np.testing.assert_allclose(got, want, rtol=2e-3 if dtype == jnp.bfloat16
                               else 1e-5)


@pytest.mark.parametrize("clip", [0.1, 1.0, 100.0])
def test_clip_accumulate_sweep(clip):
    tree = {"a": jax.random.normal(KEY, (513, 7)),
            "b": jax.random.normal(jax.random.fold_in(KEY, 1), (64,))}
    acc = jax.tree_util.tree_map(jnp.ones_like, tree)
    new_acc, norm = clip_accumulate(acc, tree, clip)
    f = float(clip_factor_ref(jnp.square(norm), clip))
    for k in tree:
        np.testing.assert_allclose(
            np.asarray(new_acc[k]),
            1.0 + f * np.asarray(tree[k]), rtol=1e-5, atol=1e-6)


def test_clip_accumulate_scale():
    """The optional scale (the streaming engine's 0/1 slot mask) multiplies
    the clip factor: scale=0 leaves the accumulator bitwise untouched,
    scale=s accumulates s·factor·Δ."""
    tree = {"a": jax.random.normal(KEY, (40, 9))}
    acc = jax.tree_util.tree_map(jnp.ones_like, tree)
    masked, norm = clip_accumulate(acc, tree, 0.5, jnp.zeros(()))
    np.testing.assert_array_equal(np.asarray(masked["a"]),
                                  np.asarray(acc["a"]))
    np.testing.assert_allclose(float(norm),
                               float(jnp.sqrt(sumsq_ref(tree["a"]))),
                               rtol=1e-6)
    half, norm = clip_accumulate(acc, tree, 0.5, jnp.full((), 0.5))
    f = 0.5 * float(clip_factor_ref(jnp.square(norm), 0.5))
    np.testing.assert_allclose(np.asarray(half["a"]),
                               1.0 + f * np.asarray(tree["a"]),
                               rtol=1e-5, atol=1e-6)


def test_dp_clip_interpret_autoselect():
    """interpret=None auto-selects by backend: on a non-TPU backend the
    kernels run through the Pallas interpreter and must agree with the
    explicit interpret=True result bitwise."""
    from repro.kernels.dp_clip import dp_clip as K
    assert K.default_interpret() == (jax.default_backend() != "tpu")
    x = jax.random.normal(KEY, (2 * K.ROWS, K.LANES))
    if K.default_interpret():
        assert float(K.sumsq(x)) == float(K.sumsq(x, interpret=True))
    tree = {"x": x}
    auto, _ = clip_accumulate({"x": jnp.zeros_like(x)}, tree, 1.0)
    forced, _ = clip_accumulate({"x": jnp.zeros_like(x)}, tree, 1.0,
                                interpret=K.default_interpret())
    np.testing.assert_array_equal(np.asarray(auto["x"]),
                                  np.asarray(forced["x"]))


def test_dp_clip_rejects_untiled_shapes():
    """Ragged (non-TILE-multiple) inputs must fail loudly at trace time —
    the grid sweep would silently misread the last block otherwise."""
    from repro.kernels.dp_clip import dp_clip as K
    good = jnp.zeros((K.ROWS, K.LANES))
    for bad in (jnp.zeros((K.ROWS + 1, K.LANES)),      # ragged sublane
                jnp.zeros((K.ROWS, K.LANES - 1)),      # wrong lane dim
                jnp.zeros((K.ROWS * K.LANES,))):       # not 2-D
        with pytest.raises(ValueError, match="tile layout"):
            K.sumsq(bad)
        with pytest.raises(ValueError, match="tile layout"):
            K.clip_accumulate_2d(bad, bad, jnp.ones(()))
    with pytest.raises(ValueError, match="share one tile layout"):
        K.clip_accumulate_2d(good, jnp.zeros((2 * K.ROWS, K.LANES)),
                             jnp.ones(()))


# ----------------------------- flash attention ------------------------------


@pytest.mark.parametrize("B,Sq,Sk,H,KV,hd", [
    (2, 256, 256, 4, 2, 64),
    pytest.param(1, 128, 512, 8, 8, 128, marks=pytest.mark.slow),
    (1, 100, 100, 2, 1, 32),     # unpadded
    pytest.param(2, 384, 384, 4, 4, 96, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 128), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Sq, Sk, H, KV, hd, causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window)
    G = H // KV
    kr = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1)
    vr = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1)
    ref = attention_ref(q.transpose(0, 2, 1, 3), kr, vr, causal=causal,
                        window=window).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


# ----------------------------- SSD scan -------------------------------------


@pytest.mark.parametrize("B,S,H,p,N", [
    (2, 256, 4, 64, 32), (1, 128, 2, 32, 16), (1, 384, 3, 16, 8),
    (1, 200, 2, 64, 64),  # unpadded seq
])
def test_ssd_scan_sweep(B, S, H, p, N):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.1
    Bm = jax.random.normal(ks[2], (B, S, N))
    Cm = jax.random.normal(ks[3], (B, S, N))
    A = -jnp.exp(jax.random.normal(ks[4], (H,)))
    y, st = ssd_scan(x, dt, Bm, Cm, A)
    yr, str_ = ssd_scan_ref(x, dt, Bm, Cm, A)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_),
                               rtol=1e-4, atol=1e-4)


def test_model_ssd_chunked_matches_sequential():
    """The pure-jnp chunked SSD inside the mamba2 model (used by every
    training forward) agrees with the sequential recurrence oracle."""
    from repro.models.mamba2 import ssd_chunked
    ks = jax.random.split(KEY, 5)
    B, S, H, p, N = 2, 256, 4, 32, 16
    x = jax.random.normal(ks[0], (B, S, H, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.1
    Bm = jax.random.normal(ks[2], (B, S, N))
    Cm = jax.random.normal(ks[3], (B, S, N))
    A = -jnp.exp(jax.random.normal(ks[4], (H,)))
    h0 = jnp.zeros((B, H, p, N), jnp.float32)
    y, hf = ssd_chunked(x, dt, Bm, Cm, A, h0)
    yr, hr = ssd_scan_ref(x, dt, Bm, Cm, A)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr),
                               rtol=1e-4, atol=1e-4)
