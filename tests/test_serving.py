"""Serving path: checkpoint roundtrip, batched generation (incl. the
``steps=0`` / ``key=None`` / correlated-row-sampling regressions),
ring-buffer positional invariants (checked on a fixed position/window grid
covering the empty / partial / exactly-full / wrapped buffer regimes).
The continuous-batching engine has its own suite in test_serve_engine.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import build
from repro.models.layers import ring_pack, ring_positions
from repro.train import checkpoint


@pytest.fixture(scope="module")
def lstm_model():
    cfg = get_config("gboard-cifg-lstm").with_(vocab=300, d_model=32, d_ff=64)
    model = build(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def test_checkpoint_roundtrip(tmp_path, lstm_model):
    cfg, model, params = lstm_model
    p = tmp_path / "ck.msgpack"
    checkpoint.save(p, params, meta={"arch": cfg.name})
    loaded, meta = checkpoint.load(p)
    assert meta["arch"] == cfg.name
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), b),
        params, loaded)


def test_generate_greedy_deterministic(lstm_model):
    cfg, model, params = lstm_model
    prompts = jnp.asarray([[2, 5, 9], [2, 7, 11]], jnp.int32)
    out1 = generate(model, params, prompts, steps=6)
    out2 = generate(model, params, prompts, steps=6)
    assert out1.shape == (2, 9)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert int(jnp.max(out1)) < cfg.vocab


def test_generate_matches_stepwise_forward(lstm_model):
    """Greedy generation must equal argmax over repeated full forwards."""
    cfg, model, params = lstm_model
    prompts = jnp.asarray([[2, 5, 9]], jnp.int32)
    out = np.asarray(generate(model, params, prompts, steps=4))[0]
    seq = [2, 5, 9]
    for _ in range(4):
        logits = model.forward(params, {"tokens": jnp.asarray([seq])})
        seq.append(int(jnp.argmax(logits[0, -1, :cfg.vocab])))
    np.testing.assert_array_equal(out, np.asarray(seq))


def test_generate_dense_with_cache():
    cfg = get_config("granite-3-2b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    out = np.asarray(generate(model, params, prompts, steps=3))[0]
    seq = [1, 2, 3, 4]
    for _ in range(3):
        logits = model.forward(params, {"tokens": jnp.asarray([seq])})
        seq.append(int(jnp.argmax(logits[0, -1, :cfg.vocab])))
    np.testing.assert_array_equal(out, np.asarray(seq))


# ------------------------- generate() decode-path regressions --------------


@pytest.mark.parametrize("steps", [0, 1, 3])
def test_generate_shape_for_all_steps(lstm_model, steps):
    """out.shape == (B, S0+steps) for every steps >= 0; steps=0 returns
    exactly the prompt (the old path emitted a bonus token from the
    prefill logits)."""
    cfg, model, params = lstm_model
    prompts = jnp.asarray([[2, 5, 9], [2, 7, 11]], jnp.int32)
    out = generate(model, params, prompts, steps=steps)
    assert out.shape == (2, 3 + steps)
    np.testing.assert_array_equal(np.asarray(out[:, :3]),
                                  np.asarray(prompts))


def test_generate_temperature_without_key_raises(lstm_model):
    """The old path crashed inside fold_in(None, t); now it's a clear
    entry-time error."""
    cfg, model, params = lstm_model
    prompts = jnp.asarray([[2, 5, 9]], jnp.int32)
    with pytest.raises(ValueError, match="PRNG key"):
        generate(model, params, prompts, steps=3, temperature=0.8)


def test_generate_negative_steps_raises(lstm_model):
    cfg, model, params = lstm_model
    with pytest.raises(ValueError, match="steps"):
        generate(model, params, jnp.asarray([[2, 5]], jnp.int32), steps=-1)


def test_generate_rows_sample_independently(lstm_model):
    """Identical prompts in one batch must draw from independent per-row
    streams (the old path folded only the step index into one shared key,
    so every row sampled the same token), deterministically given the
    key."""
    cfg, model, params = lstm_model
    prompts = jnp.asarray([[2, 5, 9]] * 2, jnp.int32)
    key = jax.random.PRNGKey(3)
    out1 = np.asarray(generate(model, params, prompts, steps=8,
                               temperature=0.9, key=key))
    out2 = np.asarray(generate(model, params, prompts, steps=8,
                               temperature=0.9, key=key))
    np.testing.assert_array_equal(out1, out2)   # deterministic given key
    assert not np.array_equal(out1[0], out1[1])  # rows independent


# ----------------------------- ring buffer properties ----------------------


@pytest.mark.parametrize("pos", [0, 1, 3, 7, 8, 9, 127, 128, 4095, 4096,
                                 10_000])
@pytest.mark.parametrize("W", [4, 8, 128, 4096])
def test_ring_positions_invariants(pos, W):
    """Slot i holds position ≡ i (mod W), within (pos−W, pos], or empty."""
    qs = np.asarray(ring_positions(jnp.asarray(pos), W))
    for i, q in enumerate(qs):
        assert q % W == i % W or q < 0
        assert q <= pos
        assert q > pos - W
    # exactly min(pos+1, W) valid slots
    assert int((qs >= 0).sum()) == min(pos + 1, W)


@pytest.mark.parametrize("S", [5, 8, 9, 15, 16, 17, 23, 40])
@pytest.mark.parametrize("W", [4, 8, 16])
def test_ring_pack_places_positions(S, W):
    """After packing a length-S prefill, slot p%W holds position p for the
    last W positions."""
    kv = jnp.arange(S, dtype=jnp.float32).reshape(1, 1, S, 1, 1)
    packed = np.asarray(ring_pack(kv, W))[0, 0, :, 0, 0]
    if S <= W:
        np.testing.assert_array_equal(packed, np.arange(S))
        return
    for p in range(S - min(S, W), S):
        assert packed[p % W] == p
