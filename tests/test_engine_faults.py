"""Production fault model: over-selection, report goals, DP-safe aborts,
crash-resumable training (`fl.faults` + the engine round protocol).

Contracts under test:

* faults *off* is the status quo: a zero-probability `FaultConfig` with
  ``report_goal == cohort`` is bit-identical to ``fault_config=None``;
* fault-on trajectories are deterministic in the fault seed and bit-exact
  across the {pods} × {shards} × {chunk} × {device, streamed} parity grid
  (fates are slot-level and replicated — where a slot computes is
  irrelevant);
* an aborted round (usable reports < report goal) leaves params/opt state
  bit-unchanged and spends no privacy budget; σ in committed rounds is
  calibrated to the report goal, never the realized survivor count;
* a run snapshotted mid-flight and restored replays to the bit-identical
  end state, faults on and off — including end-to-end through
  ``launch/train.py --crash-after/--resume`` (sha256-identical final
  checkpoint).

Shard/pod cases need forced devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest -q tests/test_engine_faults.py
"""
import hashlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ClientConfig, DPConfig, get_config
from repro.data.corpus import BigramCorpus
from repro.data.federated import FederatedDataset
from repro.data.population_store import InMemoryPopulationStore
from repro.fl.engine import SimEngine
from repro.fl.faults import FaultConfig, fault_fates
from repro.fl.round import FederatedTrainer
from repro.models import build

VOCAB = 300
ROUNDS = 3
COHORT = 32

# seed 3: a mixed stream — most rounds commit, corrupt slots appear
FAULTS = FaultConfig(seed=3, dropout_prob=0.3, straggler_prob=0.2,
                     straggler_mean_delay=2.0, round_deadline=3.0,
                     corrupt_prob=0.05)
# survival exactly 1/2 ⇒ sel_cohort 64, padded 64, chunk grid {1,2,4,8}
FAULTS_HALF = FaultConfig(seed=5, dropout_prob=0.5)

needs = {s: pytest.mark.skipif(
    len(jax.devices()) < s,
    reason=f"needs {s} devices (XLA_FLAGS="
           f"--xla_force_host_platform_device_count=8)") for s in (2, 4, 8)}


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("gboard-cifg-lstm").with_(vocab=VOCAB, d_model=24,
                                               d_ff=48)
    model = build(cfg)
    corpus = BigramCorpus(vocab_size=VOCAB, seed=0)
    ds = FederatedDataset(corpus, n_users=80, seq_len=16,
                          sentences_per_user=20)
    return cfg, model, ds


@pytest.fixture(scope="module")
def runner(setup):
    """Memoized engine runs keyed by config (the parity grid shares one
    reference run per fault config)."""
    _, model, ds = setup
    data = ds.to_device_arrays()
    cache = {}

    def run(backend="device", *, faults="mixed", noise=0.3,
            sampling="fixed", chunk=None, num_shards=1, num_pods=1):
        key = (backend, faults, noise, sampling, chunk, num_shards,
               num_pods)
        if key not in cache:
            dp = DPConfig(clients_per_round=COHORT, noise_multiplier=noise,
                          clip_norm=0.8, server_opt="momentum",
                          server_lr=0.5, server_momentum=0.9,
                          sampling=sampling)
            cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
            fc = {"mixed": FAULTS, "half": FAULTS_HALF, "off": None,
                  "zero": FaultConfig(goal_frac=1.0),
                  "seed9": FaultConfig(seed=9, dropout_prob=0.3,
                                       straggler_prob=0.2,
                                       straggler_mean_delay=2.0,
                                       round_deadline=3.0,
                                       corrupt_prob=0.05)}[faults]
            src = (data if backend == "device"
                   else InMemoryPopulationStore.from_arrays(data))
            eng = SimEngine(
                model, src, dp, cl, n_local_batches=2,
                availability=1.0 if sampling == "poisson" else 0.6,
                rounds_per_call=ROUNDS, cohort_chunk=chunk,
                num_shards=num_shards, num_pods=num_pods,
                population_backend=backend, fault_config=fc)
            state = eng.init_state(model.init(jax.random.PRNGKey(1)),
                                   seed=0)
            state, hist = eng.run(state, ROUNDS)
            cache[key] = (eng, state, hist)
        return cache[key]

    return run


def _max_leaf_diff(a, b):
    d = jax.tree_util.tree_map(
        lambda x, y: float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                           - y.astype(jnp.float32)))), a, b)
    return max(jax.tree_util.tree_leaves(d))


def _assert_bitwise(run_a, run_b, keys=("loss", "mean_update_norm",
                                        "n_clients", "noise_std")):
    _, sa, ha = run_a
    _, sb, hb = run_b
    for k in keys:
        if k in ha or k in hb:
            np.testing.assert_array_equal(np.asarray(ha[k]),
                                          np.asarray(hb[k]))
    np.testing.assert_array_equal(np.asarray(sa.participation),
                                  np.asarray(sb.participation))
    np.testing.assert_array_equal(np.asarray(sa.last_round),
                                  np.asarray(sb.last_round))
    np.testing.assert_array_equal(np.asarray(sa.key), np.asarray(sb.key))
    assert _max_leaf_diff(sa.params, sb.params) == 0.0
    assert _max_leaf_diff(sa.opt_state, sb.opt_state) == 0.0


FAULT_KEYS = ("loss", "mean_update_norm", "n_clients", "noise_std",
              "n_selected", "n_reported", "committed")


# ------------------------------------------------------- fates unit level

def test_fates_are_consistent_and_deterministic():
    cfg = FAULTS
    key = jax.random.PRNGKey(cfg.seed)
    f = fault_fates(key, 7, 256, cfg)
    g = fault_fates(key, 7, 256, cfg)
    for a, b in zip(f, g):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    rep, cor, dro, late = (np.asarray(x) for x in f)
    assert not np.any(rep & dro) and not np.any(rep & late)
    assert not np.any(dro & late)          # a dropped slot never reports late
    assert np.all(rep | dro | late)        # fates partition the slots
    assert np.all(~cor | rep)              # corrupt ⇒ reported
    # a different round index is a different draw
    h = fault_fates(key, 8, 256, cfg)
    assert np.any(np.asarray(h.reported) != rep)


def test_fates_monotone_in_dropout():
    """Monotone coupling: same uniforms, higher threshold ⇒ the dropped set
    only grows. (`test_accountant.py` builds ε-monotonicity on this.)"""
    key = jax.random.PRNGKey(0)
    prev = np.zeros(512, bool)
    for p in (0.1, 0.3, 0.6, 0.9):
        cur = np.asarray(fault_fates(key, 0, 512,
                                     FaultConfig(dropout_prob=p)).dropped)
        assert np.all(prev <= cur)
        prev = cur


def test_fault_config_validation():
    with pytest.raises(ValueError):
        FaultConfig(dropout_prob=1.0)
    with pytest.raises(ValueError):
        FaultConfig(straggler_mean_delay=0.0)
    with pytest.raises(ValueError):
        FaultConfig(goal_frac=0.0)
    with pytest.raises(ValueError):
        FaultConfig(report_goal=0)
    fc = FaultConfig(dropout_prob=0.5)
    assert fc.over_selection(32) == 64
    assert fc.resolve_report_goal(32) == 26          # ceil(0.8·32)
    assert FaultConfig(report_goal=30).resolve_report_goal(32) == 30
    assert FaultConfig(dropout_prob=0.5,
                       over_select=False).over_selection(32) == 32


# ---------------------------------------------------- faults-off invariance

def test_zero_prob_config_is_bit_identical_to_none(runner):
    """FaultConfig(0 probs, report_goal == cohort) traces the fault branch
    but must reproduce the fault-free trajectory bit-for-bit."""
    _assert_bitwise(runner(faults="off"), runner(faults="zero"))


# ------------------------------------------------- determinism in the seed

def test_fault_seed_determinism(runner, setup):
    _, model, ds = setup
    data = ds.to_device_arrays()
    dp = DPConfig(clients_per_round=COHORT, noise_multiplier=0.3,
                  clip_norm=0.8, server_opt="momentum", server_lr=0.5,
                  server_momentum=0.9)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    eng = SimEngine(model, data, dp, cl, n_local_batches=2,
                    availability=0.6, rounds_per_call=ROUNDS,
                    fault_config=FAULTS)
    state = eng.init_state(model.init(jax.random.PRNGKey(1)), seed=0)
    state, hist = eng.run(state, ROUNDS)
    _assert_bitwise(runner(faults="mixed"), (eng, state, hist),
                    keys=FAULT_KEYS)
    # ... and a different fault seed gives a different trajectory
    _, _, h9 = runner(faults="seed9")
    ref = runner(faults="mixed")[2]
    assert np.any(np.asarray(h9["n_reported"])
                  != np.asarray(ref["n_reported"]))


# --------------------------------------------------- fault-on parity grid

def test_fault_parity_streamed(runner):
    _assert_bitwise(runner("device"), runner("streamed"), keys=FAULT_KEYS)


def test_fault_parity_poisson_streamed(runner):
    _assert_bitwise(runner("device", sampling="poisson"),
                    runner("streamed", sampling="poisson"),
                    keys=FAULT_KEYS)


@pytest.mark.parametrize("chunk", [1, 2])
def test_fault_parity_chunk(runner, chunk):
    _assert_bitwise(runner("device", faults="half"),
                    runner("device", faults="half", chunk=chunk),
                    keys=FAULT_KEYS)


@pytest.mark.slow
@pytest.mark.parametrize("chunk", [4, 8])
def test_fault_parity_chunk_wide(runner, chunk):
    _assert_bitwise(runner("device", faults="half"),
                    runner("device", faults="half", chunk=chunk),
                    keys=FAULT_KEYS)


@needs[2]
def test_fault_parity_sharded(runner):
    _assert_bitwise(runner("device"), runner("device", num_shards=2),
                    keys=FAULT_KEYS)


@needs[4]
def test_fault_parity_pods(runner):
    _assert_bitwise(runner("device"),
                    runner("device", num_pods=2, num_shards=2),
                    keys=FAULT_KEYS)


@needs[4]
def test_fault_parity_pods_streamed(runner):
    _assert_bitwise(runner("device"),
                    runner("streamed", num_pods=2, num_shards=2),
                    keys=FAULT_KEYS)


@pytest.mark.slow
@needs[8]
def test_fault_parity_pods_wide(runner):
    _assert_bitwise(runner("device"),
                    runner("device", num_pods=2, num_shards=4),
                    keys=FAULT_KEYS)


# ------------------------------------------------ protocol-level semantics

def test_over_selection_sizing(runner):
    eng, _, hist = runner(faults="mixed")
    assert eng.sel_cohort == FAULTS.over_selection(COHORT)
    assert eng.report_goal == FAULTS.resolve_report_goal(COHORT)
    assert np.all(np.asarray(hist["n_selected"]) == eng.sel_cohort)
    # survivors: reported ≥ accepted, selected ≥ reported
    assert np.all(np.asarray(hist["n_reported"])
                  <= np.asarray(hist["n_selected"]))
    assert np.all(np.asarray(hist["n_clients"])
                  <= np.asarray(hist["n_reported"]))


def test_sigma_calibrated_to_report_goal(runner):
    """σ = zS / report_goal in every round — committed or not, whatever the
    realized survivor count."""
    eng, _, hist = runner(faults="mixed")
    expect = np.float32(0.3 * 0.8 / np.float32(eng.report_goal))
    np.testing.assert_array_equal(np.asarray(hist["noise_std"]),
                                  np.full(ROUNDS, expect))


def test_commit_iff_goal_met(runner):
    eng, _, hist = runner(faults="mixed")
    np.testing.assert_array_equal(
        np.asarray(hist["committed"]),
        np.asarray(hist["n_clients"]) >= eng.report_goal)
    assert np.all(np.isfinite(np.asarray(hist["loss"])))


def _trainer(setup, fc, **kw):
    _, model, ds = setup
    dp = DPConfig(clients_per_round=8, noise_multiplier=0.3, clip_norm=0.8,
                  server_opt="momentum", server_lr=0.5, server_momentum=0.9)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    kw.setdefault("backend", "engine")
    return FederatedTrainer(model, ds, dp, cl, seed=0, n_local_batches=2,
                            rounds_per_call=4, fault_config=fc, **kw)


def test_abort_leaves_state_bit_unchanged(setup):
    """dropout 0.9 with no over-selection and goal == cohort: every round
    misses the goal ⇒ params/opt never move, accountant never steps."""
    fc = FaultConfig(seed=1, dropout_prob=0.9, over_select=False,
                     report_goal=8)
    tr = _trainer(setup, fc)
    before = jax.device_get(tr._estate)
    tr.train(3)
    after = tr._estate
    assert not any(r["committed"] for r in tr.state.history)
    assert _max_leaf_diff(before.params, after.params) == 0.0
    assert _max_leaf_diff(before.opt_state, after.opt_state) == 0.0
    assert tr.accountant.rounds == 0
    # the PRNG chain still advanced: aborts don't replay sampling
    assert np.any(np.asarray(before.key) != np.asarray(after.key))


def test_trainer_accounts_committed_rounds_only(setup):
    tr = _trainer(setup, FAULTS)
    tr.train(6)
    committed = sum(r["committed"] for r in tr.state.history)
    assert tr.accountant.rounds == committed
    # corrupt rejection shows up as accepted < reported in some round
    assert all(r["n_clients"] <= r["n_reported"]
               for r in tr.state.history)


def test_host_backend_rejects_fault_config(setup):
    with pytest.raises(ValueError, match="engine-backend"):
        _trainer(setup, FAULTS, backend="host")


def test_materializing_path_rejects_fault_config(setup):
    with pytest.raises(ValueError, match="cohort_chunk"):
        _trainer(setup, FAULTS, cohort_chunk=0)


# ----------------------------------------------------- crash-resume parity

@pytest.mark.parametrize("fc", [None, FAULTS],
                         ids=["faults-off", "faults-on"])
def test_save_restore_resumes_bit_exact(setup, tmp_path, fc):
    ref = _trainer(setup, fc)
    ref.train(8)
    a = _trainer(setup, fc)
    a.train(5)
    a.save_run_state(tmp_path / "state.msgpack")
    b = _trainer(setup, fc)
    done = b.restore_run_state(tmp_path / "state.msgpack")
    assert done == 5
    b.train(8 - done)
    assert _max_leaf_diff(ref.state.params, b.state.params) == 0.0
    assert _max_leaf_diff(ref.state.opt_state, b.state.opt_state) == 0.0
    assert ref.state.history == b.state.history
    assert ref.accountant.rounds == b.accountant.rounds
    np.testing.assert_array_equal(ref.participation, b.participation)


def test_restore_rejects_wrong_kind(setup, tmp_path):
    from repro.train import checkpoint
    tr = _trainer(setup, None)
    checkpoint.save(tmp_path / "model.msgpack", tr.state.params,
                    meta={"kind": "model"})
    with pytest.raises(checkpoint.CheckpointError, match="run-state"):
        tr.restore_run_state(tmp_path / "model.msgpack")


def _cli(tmp_path, extra):
    from repro.launch import train as train_cli
    argv = ["train", "--reduced", "--vocab", "120", "--rounds", "6",
            "--n-users", "40", "--clients-per-round", "8",
            "--noise-multiplier", "0.3", "--availability", "0.6",
            "--rounds-per-call", "2", "--seed", "0",
            "--out", str(tmp_path)] + extra
    old = sys.argv
    sys.argv = argv
    try:
        train_cli.main()
    finally:
        sys.argv = old
    ck = tmp_path / "gboard-cifg-lstm_r6.msgpack"
    return hashlib.sha256(ck.read_bytes()).hexdigest() if ck.exists() \
        else None


@pytest.mark.slow
@pytest.mark.parametrize("fault_args", [[], ["--fault-dropout", "0.3",
                                             "--fault-corrupt", "0.05"]],
                         ids=["faults-off", "faults-on"])
def test_cli_crash_resume_sha256_identical(tmp_path, fault_args):
    """launch/train.py killed after round 3 and restarted with --resume
    produces a byte-identical final checkpoint."""
    ref = _cli(tmp_path / "ref", fault_args)
    assert ref is not None
    crashed = _cli(tmp_path / "res", fault_args
                   + ["--checkpoint-every", "2", "--crash-after", "3"])
    assert crashed is None          # crashed before the final checkpoint
    resumed = _cli(tmp_path / "res", fault_args
                   + ["--checkpoint-every", "2", "--resume"])
    assert resumed == ref
