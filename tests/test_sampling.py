"""Client-sampling invariants: host (`fl.sampling` / `PopulationSim`) and
device (`fl.engine.sample_cohort`) paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl.engine import sample_cohort
from repro.fl.population import PopulationSim
from repro.fl.sampling import fixed_size_sample, poisson_sample, sample_round

# ----------------------------- fixed-size (host) ---------------------------


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("n,k", [(100, 17), (50, 50), (10, 40), (1, 5)])
def test_fixed_size_exactly_min_k_unique(seed, n, k):
    """Returns exactly min(k, |checked|) ids, all unique, all from the pool."""
    rng = np.random.default_rng(seed)
    ids = np.arange(1000, 1000 + n)
    out = fixed_size_sample(rng, ids, k)
    assert out.shape[0] == min(k, n)
    assert len(np.unique(out)) == out.shape[0]
    assert np.isin(out, ids).all()


@pytest.mark.parametrize("seed", [0, 5])
def test_fixed_size_weighted_zero_weight_excluded(seed):
    rng = np.random.default_rng(seed)
    ids = np.arange(60)
    w = np.ones(60)
    w[::2] = 0.0                      # exclude all even ids
    w /= w.sum()
    out = fixed_size_sample(rng, ids, 25, weights=w)
    assert out.shape[0] == 25
    assert (out % 2 == 1).all()


def test_sample_round_fixed_size_and_marks():
    pop = PopulationSim(200, availability=0.5, seed=3)
    rng = np.random.default_rng(3)
    for r in range(3):
        ids = sample_round(pop, rng, r, 23)
        assert ids.shape[0] == 23
        assert len(np.unique(ids)) == 23
        assert (pop._last_round[ids] == r).all()


def test_sample_round_caps_at_checked_in():
    """|cohort| = min(qN, #checked-in): tiny availability, huge request."""
    pop = PopulationSim(40, availability=0.2, seed=0)
    rng = np.random.default_rng(0)
    with pytest.warns(RuntimeWarning, match="calibrated"):
        ids = sample_round(pop, rng, 0, 1000)
    checked = (pop._last_round == 0).sum()
    assert ids.shape[0] == checked <= 40


def test_short_round_warns_realized_vs_target():
    """An under-populated pool shrinking the round is never silent — σ was
    calibrated to the full round size."""
    rng = np.random.default_rng(0)
    with pytest.warns(RuntimeWarning, match=r"only 10 of the 40"):
        out = fixed_size_sample(rng, np.arange(10), 40)
    assert out.shape[0] == 10


def test_full_round_does_not_warn():
    rng = np.random.default_rng(0)
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        out = fixed_size_sample(rng, np.arange(50), 40)
    assert out.shape[0] == 40


def test_round_below_report_goal_raises():
    """With a report goal the host sampler aborts instead of releasing a
    round smaller than the σ calibration."""
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="report goal"):
        fixed_size_sample(rng, np.arange(10), 40, min_size=12)
    # met goal: no raise, just the short-round warning
    with pytest.warns(RuntimeWarning):
        out = fixed_size_sample(rng, np.arange(10), 40, min_size=8)
    assert out.shape[0] == 10
    pop = PopulationSim(40, availability=0.2, seed=0)
    with pytest.raises(ValueError, match="report goal"):
        sample_round(pop, rng, 0, 1000, min_size=39)


# ----------------------------- Poisson (host) ------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("q", [0.05, 0.2])
def test_poisson_round_size_concentrates(seed, q):
    """Poisson round sizes average qN with binomial-scale spread."""
    rng = np.random.default_rng(seed)
    N, trials = 2000, 40
    ids = np.arange(N)
    sizes = np.array([poisson_sample(rng, ids, q).shape[0]
                      for _ in range(trials)])
    mean, std = q * N, np.sqrt(N * q * (1 - q))
    assert abs(sizes.mean() - mean) < 4 * std / np.sqrt(trials)
    assert (np.abs(sizes - mean) < 6 * std).all()


# ----------------------------- device sampler ------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_device_sample_exact_k_unique(seed):
    key = jax.random.PRNGKey(seed)
    w = jnp.ones((120,))
    avail = jnp.ones((120,), bool)
    ids = np.asarray(sample_cohort(key, w, avail, 30))
    assert ids.shape[0] == 30
    assert len(np.unique(ids)) == 30


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_device_sample_zero_weight_excluded(seed):
    """Weight 0 (and unavailable) devices are never selected while enough
    positive-weight devices exist."""
    key = jax.random.PRNGKey(seed)
    w = jnp.ones((100,)).at[::2].set(0.0)        # even ids weight 0
    avail = jnp.ones((100,), bool).at[1].set(False)  # id 1 unavailable
    ids = np.asarray(sample_cohort(key, w, avail, 40))
    assert ids.shape[0] == 40
    assert (ids % 2 == 1).all()
    assert 1 not in ids


def test_device_sample_weights_bias_selection():
    """A 100×-weighted subgroup is selected far above its population share."""
    heavy = jnp.zeros((200,), bool).at[:20].set(True)
    w = jnp.where(heavy, 100.0, 1.0)
    avail = jnp.ones((200,), bool)
    hits = 0
    for seed in range(30):
        ids = np.asarray(sample_cohort(jax.random.PRNGKey(seed), w, avail, 20))
        hits += int((ids < 20).sum())
    # uniform sampling would give E[hits] = 30·20·(20/200) = 60
    assert hits > 300
