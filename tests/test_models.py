"""Per-architecture smoke tests: reduced variant of each assigned family,
one forward/train step on CPU, shape + NaN assertions, and
prefill→decode consistency against the teacher-forced forward pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models import build
from repro.models.layers import pad_vocab

B, S = 2, 16

# tier-1 keeps one representative per family; same-family duplicates run in
# the slow tier (--runslow) to hold `pytest -x -q` under the time budget
DUP_FAMILY_ARCHS = {"granite-moe-3b-a800m", "stablelm-12b", "phi3-medium-14b"}
# heaviest prefill→decode consistency checks (state/cache paths) — slow tier
HEAVY_PREFILL = {"mamba2-370m", "zamba2-2.7b", "whisper-small", "olmoe-1b-7b"}


def _batch(cfg, key):
    kt = jax.random.fold_in(key, 1)
    tokens = jax.random.randint(kt, (B, S + 1), 0, cfg.vocab)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.n_audio_frames, cfg.d_model))
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 3), (B, cfg.n_image_tokens, cfg.d_model))
    return batch


@pytest.fixture(scope="module",
                params=[pytest.param(a, marks=pytest.mark.slow)
                        if a in DUP_FAMILY_ARCHS else a for a in ALL_ARCHS])
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    return request.param, cfg, model, params, _batch(cfg, key)


def test_forward_shapes_and_finite(arch_setup):
    arch, cfg, model, params, batch = arch_setup
    logits = model.forward(params, batch)
    assert logits.shape == (B, S, pad_vocab(cfg.vocab))
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


# heaviest backward-pass compiles; their families keep gradient coverage in
# tier-1 via mamba2 (ssm core) and olmoe (moe)
HEAVY_TRAIN = {"zamba2-2.7b", "whisper-small"}


def test_train_step_no_nan(arch_setup, runslow):
    arch, cfg, model, params, batch = arch_setup
    if arch in HEAVY_TRAIN and not runslow:
        pytest.skip("slow: pass --runslow to include")
    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0.0


def test_prefill_decode_matches_forward(arch_setup, runslow):
    """Decoding token-by-token from a prefix cache must reproduce the
    teacher-forced logits (the KV-cache/state path is consistent)."""
    arch, cfg, model, params, batch = arch_setup
    if arch in HEAVY_PREFILL and not runslow:
        pytest.skip("slow: pass --runslow to include")
    # MoE: the inference path is dropless (see moe.moe_ffn); score the
    # reference forward dropless too so both paths dispatch identically.
    kw = {"dropless": True} if cfg.family == "moe" else {}
    logits_full = model.forward(params, batch, **kw)
    split = S // 2
    pre = {k: (v[:, :split] if k in ("tokens", "labels") else v)
           for k, v in batch.items()}
    pre.pop("labels")
    last, cache = model.prefill(params, pre, max_len=S)
    np.testing.assert_allclose(
        np.asarray(last, np.float32),
        np.asarray(logits_full[:, split - 1, :], np.float32),
        rtol=2e-2, atol=2e-2)
    # decode a few steps
    for t in range(split, min(split + 3, S)):
        logits_t, cache = model.decode_step(params, batch["tokens"][:, t],
                                            cache)
        np.testing.assert_allclose(
            np.asarray(logits_t, np.float32),
            np.asarray(logits_full[:, t, :], np.float32),
            rtol=2e-2, atol=2e-2)


def test_sliding_window_variant_runs(arch_setup):
    arch, cfg, model, params, batch = arch_setup
    if cfg.family not in ("dense", "moe", "vlm", "encdec"):
        pytest.skip("window only applies to attention families")
    cfgw = cfg.with_(attn_window=4)
    mw = build(cfgw)
    logits = mw.forward(params, batch)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_param_count_full_config():
    """Full (non-reduced) configs hit their nameplate scale (±40%)."""
    expected = {"phi3-mini-3.8b": 3.8e9, "phi3-medium-14b": 14e9,
                "chameleon-34b": 34e9, "mamba2-370m": 3.7e8,
                "granite-3-2b": 2.5e9, "stablelm-12b": 12e9,
                "zamba2-2.7b": 2.7e9}
    for arch, n_exp in expected.items():
        cfg = get_config(arch)
        model = build(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes))
        assert 0.6 * n_exp < n < 1.6 * n_exp, (arch, n, n_exp)


def test_gboard_lstm_is_1p3m():
    cfg = get_config("gboard-cifg-lstm")
    model = build(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes))
    # paper: ~1.3M parameters (vocab padding adds a little)
    assert 1.0e6 < n < 1.6e6, n
