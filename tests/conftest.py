"""Shared pytest config: the ``slow`` marker.

Tier-1 (``PYTHONPATH=src python -m pytest -x -q``) must finish in well under
two minutes, so anything heavier — full compile sweeps, long training runs —
is marked ``@pytest.mark.slow`` and only runs with ``--runslow``.
"""
import pytest


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="also run tests marked @pytest.mark.slow")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: heavy test (compile sweep / long training), "
                   "skipped unless --runslow is given")


@pytest.fixture
def runslow(request):
    """For runtime skips of heavy cases inside otherwise-fast parametrized
    tests (collection-time marks can't see the fixture parameter)."""
    return request.config.getoption("--runslow")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip = pytest.mark.skip(reason="slow: pass --runslow to include")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
