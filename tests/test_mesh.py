"""Direct coverage for `launch/mesh.py` (previously only exercised
indirectly through the sharded-engine suite).

`make_production_mesh` builds the full (data, model) / (pod, data, model)
device mesh; `make_cohort_mesh` builds the engine's batch-axes slice —
1-D ``(data,)`` or 2-D ``(pod, data)`` — and must reject model-parallel
configs with an actionable error. CPU runs force devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``; cases needing more
devices than visible are skipped.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import MULTI_POD, SINGLE_POD, MeshConfig
from repro.launch.mesh import (COHORT_AXES, make_cohort_mesh,
                               make_production_mesh, mesh_config)
from repro.sharding.specs import sim_mesh_config

NDEV = len(jax.devices())


def needs(n):
    return pytest.mark.skipif(
        NDEV < n, reason=f"needs {n} devices (XLA_FLAGS="
                         f"--xla_force_host_platform_device_count=16)")


# -------------------------------------------------- make_production_mesh


def test_mesh_config_selects_pod_layout():
    assert mesh_config() is SINGLE_POD
    assert mesh_config(multi_pod=True) is MULTI_POD
    assert SINGLE_POD.axes == ("data", "model")
    assert MULTI_POD.axes == ("pod", "data", "model")
    assert SINGLE_POD.n_devices == 256 and MULTI_POD.n_devices == 512


@pytest.mark.parametrize("multi_pod,shape,axes", [
    pytest.param(False, (2, 2), ("data", "model"), marks=needs(4)),
    pytest.param(True, (2, 2, 2), ("pod", "data", "model"), marks=needs(8)),
    pytest.param(True, (2, 4, 2), ("pod", "data", "model"), marks=needs(16)),
])
def test_make_production_mesh_shape_and_axes(multi_pod, shape, axes):
    """The shape override keeps the production axis names and order — a
    test-scale mesh is the real mesh with smaller extents, so specs built
    against it transfer."""
    mesh = make_production_mesh(multi_pod=multi_pod, shape=shape)
    assert mesh.axis_names == axes
    assert mesh.devices.shape == shape
    assert mesh.devices.size == int(np.prod(shape))


def test_make_production_mesh_shape_arity_mismatch_raises():
    with pytest.raises(ValueError, match="one entry per"):
        make_production_mesh(multi_pod=True, shape=(2, 2))
    with pytest.raises(ValueError, match="one entry per"):
        make_production_mesh(shape=(2, 2, 2))


# ----------------------------------------------------- make_cohort_mesh


def test_cohort_axes_constant_matches_sim_configs():
    assert tuple(sim_mesh_config(2).axes) in COHORT_AXES
    assert tuple(sim_mesh_config(2, 2).axes) in COHORT_AXES


@pytest.mark.parametrize("shards,pods", [
    pytest.param(2, 1, marks=needs(2)),
    pytest.param(2, 2, marks=needs(4)),
    pytest.param(4, 2, marks=needs(8)),
])
def test_make_cohort_mesh_layouts(shards, pods):
    """1-D and 2-D cohort meshes come back with the requested extents, the
    batch axis names, and a pod-major device layout (C order: pod p rows
    are contiguous runs of `shards` devices)."""
    cfg = sim_mesh_config(shards, pods)
    mesh = make_cohort_mesh(cfg)
    assert mesh.axis_names == cfg.axes
    assert mesh.devices.shape == cfg.shape
    flat = list(mesh.devices.reshape(-1))
    assert flat == jax.devices()[:pods * shards]  # first-N, row-major


def test_make_cohort_mesh_rejects_model_axis_configs():
    """The full production configs (they carry the model axis) must fail
    with an error that names the cohort entry point — not be flattened."""
    for cfg in (SINGLE_POD, MULTI_POD,
                MeshConfig((1, 1, 1), ("pod", "data", "model")),
                MeshConfig((4,), ("model",))):
        with pytest.raises(ValueError, match="sim_mesh_config"):
            make_cohort_mesh(cfg)


def test_make_cohort_mesh_insufficient_devices_names_the_fix():
    """Asking for more devices than visible fails at construction with the
    XLA_FLAGS escape hatch in the message (and the exact count needed)."""
    cfg = sim_mesh_config(NDEV + 1)
    with pytest.raises(ValueError) as ei:
        make_cohort_mesh(cfg)
    msg = str(ei.value)
    assert "xla_force_host_platform_device_count" in msg
    assert str(NDEV + 1) in msg
    # 2-D shortfalls report the *total* device need, not a per-axis count
    cfg2 = MeshConfig((NDEV + 1, 2), ("pod", "data"))
    with pytest.raises(ValueError, match=str(2 * (NDEV + 1))):
        make_cohort_mesh(cfg2)
