"""Adaptive clipping (beyond-paper, [TAM19]) converges S_t to the target
quantile of the user-update-norm distribution."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adaptive_clip import (adaptive_rounds, init_adaptive_clip,
                                      update_clip_norm)


def test_converges_to_quantile():
    rng = np.random.default_rng(0)
    # stationary norm distribution ~ lognormal, true 0.9-quantile known
    norms = rng.lognormal(mean=0.0, sigma=0.5, size=(200, 100))
    q90 = float(np.quantile(norms, 0.9))
    state = init_adaptive_clip(initial_clip=0.05, target_quantile=0.9,
                               lr=0.3, noise_multiplier_b=1.0)
    state, traj = adaptive_rounds(list(norms), 100, jax.random.PRNGKey(0),
                                  state)
    tail = np.mean(traj[-30:])
    assert abs(tail - q90) / q90 < 0.25, (tail, q90)
    assert traj[0] < traj[-1]  # grew from the too-small start


def test_tracks_shrinking_norms():
    """As training converges, update norms shrink — S_t must follow down."""
    rng = np.random.default_rng(1)
    rounds = [rng.lognormal(0.0, 0.3, 50) * (1.0 - 0.004 * t)
              for t in range(150)]
    state = init_adaptive_clip(initial_clip=2.0, target_quantile=0.5,
                               lr=0.3, noise_multiplier_b=1.0)
    state, traj = adaptive_rounds(rounds, 50, jax.random.PRNGKey(1), state)
    assert np.mean(traj[-10:]) < np.mean(traj[20:30])


def test_noise_applied():
    state = init_adaptive_clip(noise_multiplier_b=100.0)
    outs = set()
    for seed in range(5):
        s2 = update_clip_norm(state, jnp.asarray(0.9), 100,
                              jax.random.PRNGKey(seed))
        outs.add(round(float(s2.clip_norm), 6))
    assert len(outs) > 1  # DP noise on the fraction actually perturbs
