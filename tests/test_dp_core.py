"""Unit + property tests for the paper's core mechanism (Algorithm 1).

Property-style invariants run over a fixed (scale, clip, seed) grid rather
than hypothesis draws — deterministic, same coverage of the clipped /
unclipped / extreme-scale branches.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DPConfig
from repro.core.clipping import clip_by_global_norm
from repro.core.dp_fedavg import aggregate, finalize_round
from repro.core.server_optim import apply_update, init_state
from repro.utils.pytree import tree_global_norm


def _tree(key, scale=1.0):
    k1, k2 = jax.random.split(key)
    return {"a": scale * jax.random.normal(k1, (17, 9)),
            "b": {"c": scale * jax.random.normal(k2, (33,))}}


# ----------------------------- clipping (property) -------------------------


@pytest.mark.parametrize("scale", [1e-3, 0.05, 1.0, 31.6, 1e3])
@pytest.mark.parametrize("clip", [0.05, 0.8, 10.0])
@pytest.mark.parametrize("seed", [0, 7, 123456])
def test_clip_norm_bounded(scale, clip, seed):
    """Invariant: ‖clip_S(Δ)‖ ≤ S (+ float slack) and direction preserved."""
    tree = _tree(jax.random.PRNGKey(seed), scale)
    clipped, norm, was_clipped = clip_by_global_norm(tree, clip)
    cn = float(tree_global_norm(clipped))
    assert cn <= clip * (1 + 1e-4) + 1e-6
    if float(norm) <= clip:
        # no-op below threshold
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   np.asarray(tree["a"]), rtol=1e-5)
        assert float(was_clipped) == 0.0
    else:
        assert float(was_clipped) == 1.0
        # direction preserved: clipped = tree * S/‖tree‖
        f = clip / float(norm)
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   f * np.asarray(tree["a"]), rtol=1e-4)


# ----------------------------- aggregation ---------------------------------


def test_aggregate_matches_manual():
    dp = DPConfig(clip_norm=0.5, noise_multiplier=0.0, clients_per_round=4)
    key = jax.random.PRNGKey(0)
    users = jax.vmap(lambda k: _tree(k, 2.0))(jax.random.split(key, 4))
    delta, stats = aggregate(users, jax.random.PRNGKey(1), dp)
    # every user has norm >> 0.5 → each clipped to exactly 0.5, mean of 4
    assert float(stats.frac_clipped) == 1.0
    manual = []
    for i in range(4):
        u = jax.tree_util.tree_map(lambda l: l[i], users)
        n = float(tree_global_norm(u))
        manual.append(jax.tree_util.tree_map(lambda l: l * (0.5 / n), u))
    mean = jax.tree_util.tree_map(
        lambda *ls: sum(ls) / 4.0, *manual)
    np.testing.assert_allclose(np.asarray(delta["a"]),
                               np.asarray(mean["a"]), rtol=1e-4)


def test_noise_statistics():
    """σ = z·S/qN and the noise is actually ~N(0, σ²) in f32."""
    dp = DPConfig(clip_norm=0.8, noise_multiplier=0.8, clients_per_round=100)
    zeros = {"w": jnp.zeros((200, 500))}
    delta, stats = finalize_round(zeros, 100, jax.random.PRNGKey(0), dp)
    sigma = 0.8 * 0.8 / 100
    assert abs(float(stats.noise_std) - sigma) < 1e-8
    emp = float(jnp.std(delta["w"]))
    assert abs(emp - sigma) / sigma < 0.02
    assert delta["w"].dtype == jnp.float32  # DP noise must be f32


# ----------------------------- server optimizers ---------------------------


@pytest.mark.parametrize("opt", ["sgd", "momentum", "adam"])
def test_server_optimizers_step(opt):
    dp = DPConfig(server_opt=opt, server_lr=0.1, server_momentum=0.9)
    params = {"w": jnp.ones((4, 4))}
    state = init_state(params)
    delta = {"w": jnp.full((4, 4), 0.5)}
    p1, state = apply_update(params, delta, state, dp)
    if opt == "sgd":
        np.testing.assert_allclose(np.asarray(p1["w"]), 1.0 + 0.1 * 0.5,
                                   rtol=1e-6)
    if opt == "momentum":  # Nesterov first step: m=Δ, step = μΔ + Δ
        np.testing.assert_allclose(np.asarray(p1["w"]),
                                   1.0 + 0.1 * (0.9 * 0.5 + 0.5), rtol=1e-6)
    if opt == "adam":      # bias-corrected first step ≈ lr·sign·(1)
        np.testing.assert_allclose(np.asarray(p1["w"]), 1.0 + 0.1, rtol=1e-3)
    p2, state = apply_update(p1, delta, state, dp)
    assert np.all(np.asarray(p2["w"]) > np.asarray(p1["w"]))


def test_momentum_accumulates():
    dp = DPConfig(server_opt="momentum", server_lr=1.0, server_momentum=0.9)
    params = {"w": jnp.zeros(())}
    state = init_state(params)
    delta = {"w": jnp.ones(())}
    vals = []
    for _ in range(30):
        params, state = apply_update(params, delta, state, dp)
        vals.append(float(params["w"]))
    inc = np.diff(vals)
    assert inc[-1] > inc[0]                 # momentum ramps up
    assert inc[-1] < 1.0 / (1 - 0.9) * 2.2  # bounded by 1/(1−μ) scale
