"""Unit + property tests for the paper's core mechanism (Algorithm 1).

Property-style invariants run over a fixed (scale, clip, seed) grid rather
than hypothesis draws — deterministic, same coverage of the clipped /
unclipped / extreme-scale branches. The "DP invariants under sharding"
section checks the properties the cohort-sharded engine's privacy claim
rests on: single-device sensitivity of the aggregated update stays ≤ S/(qN)
under every aggregation topology, Poisson-excluded slots contribute exactly
zero, and participation accounting is backend- and shard-count-invariant.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DPConfig
from repro.core.clipping import clip_by_global_norm
from repro.core.dp_fedavg import aggregate, finalize_round
from repro.core.server_optim import apply_update, init_state
from repro.fl.engine import canon_pad, cohort_sum, poisson_select
from repro.utils.pytree import tree_global_norm


def _tree(key, scale=1.0):
    k1, k2 = jax.random.split(key)
    return {"a": scale * jax.random.normal(k1, (17, 9)),
            "b": {"c": scale * jax.random.normal(k2, (33,))}}


# ----------------------------- clipping (property) -------------------------


@pytest.mark.parametrize("scale", [1e-3, 0.05, 1.0, 31.6, 1e3])
@pytest.mark.parametrize("clip", [0.05, 0.8, 10.0])
@pytest.mark.parametrize("seed", [0, 7, 123456])
def test_clip_norm_bounded(scale, clip, seed):
    """Invariant: ‖clip_S(Δ)‖ ≤ S (+ float slack) and direction preserved."""
    tree = _tree(jax.random.PRNGKey(seed), scale)
    clipped, norm, was_clipped = clip_by_global_norm(tree, clip)
    cn = float(tree_global_norm(clipped))
    assert cn <= clip * (1 + 1e-4) + 1e-6
    if float(norm) <= clip:
        # no-op below threshold
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   np.asarray(tree["a"]), rtol=1e-5)
        assert float(was_clipped) == 0.0
    else:
        assert float(was_clipped) == 1.0
        # direction preserved: clipped = tree * S/‖tree‖
        f = clip / float(norm)
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   f * np.asarray(tree["a"]), rtol=1e-4)


# ----------------------------- aggregation ---------------------------------


def test_aggregate_matches_manual():
    dp = DPConfig(clip_norm=0.5, noise_multiplier=0.0, clients_per_round=4)
    key = jax.random.PRNGKey(0)
    users = jax.vmap(lambda k: _tree(k, 2.0))(jax.random.split(key, 4))
    delta, stats = aggregate(users, jax.random.PRNGKey(1), dp)
    # every user has norm >> 0.5 → each clipped to exactly 0.5, mean of 4
    assert float(stats.frac_clipped) == 1.0
    manual = []
    for i in range(4):
        u = jax.tree_util.tree_map(lambda l: l[i], users)
        n = float(tree_global_norm(u))
        manual.append(jax.tree_util.tree_map(lambda l: l * (0.5 / n), u))
    mean = jax.tree_util.tree_map(
        lambda *ls: sum(ls) / 4.0, *manual)
    np.testing.assert_allclose(np.asarray(delta["a"]),
                               np.asarray(mean["a"]), rtol=1e-4)


def test_noise_statistics():
    """σ = z·S/qN and the noise is actually ~N(0, σ²) in f32."""
    dp = DPConfig(clip_norm=0.8, noise_multiplier=0.8, clients_per_round=100)
    zeros = {"w": jnp.zeros((200, 500))}
    delta, stats = finalize_round(zeros, 100, jax.random.PRNGKey(0), dp)
    sigma = 0.8 * 0.8 / 100
    assert abs(float(stats.noise_std) - sigma) < 1e-8
    emp = float(jnp.std(delta["w"]))
    assert abs(emp - sigma) / sigma < 0.02
    assert delta["w"].dtype == jnp.float32  # DP noise must be f32


# ----------------------------- server optimizers ---------------------------


@pytest.mark.parametrize("opt", ["sgd", "momentum", "adam"])
def test_server_optimizers_step(opt):
    dp = DPConfig(server_opt=opt, server_lr=0.1, server_momentum=0.9)
    params = {"w": jnp.ones((4, 4))}
    state = init_state(params)
    delta = {"w": jnp.full((4, 4), 0.5)}
    p1, state = apply_update(params, delta, state, dp)
    if opt == "sgd":
        np.testing.assert_allclose(np.asarray(p1["w"]), 1.0 + 0.1 * 0.5,
                                   rtol=1e-6)
    if opt == "momentum":  # Nesterov first step: m=Δ, step = μΔ + Δ
        np.testing.assert_allclose(np.asarray(p1["w"]),
                                   1.0 + 0.1 * (0.9 * 0.5 + 0.5), rtol=1e-6)
    if opt == "adam":      # bias-corrected first step ≈ lr·sign·(1)
        np.testing.assert_allclose(np.asarray(p1["w"]), 1.0 + 0.1, rtol=1e-3)
    p2, state = apply_update(p1, delta, state, dp)
    assert np.all(np.asarray(p2["w"]) > np.asarray(p1["w"]))


def test_momentum_accumulates():
    dp = DPConfig(server_opt="momentum", server_lr=1.0, server_momentum=0.9)
    params = {"w": jnp.zeros(())}
    state = init_state(params)
    delta = {"w": jnp.ones(())}
    vals = []
    for _ in range(30):
        params, state = apply_update(params, delta, state, dp)
        vals.append(float(params["w"]))
    inc = np.diff(vals)
    assert inc[-1] > inc[0]                 # momentum ramps up
    assert inc[-1] < 1.0 / (1 - 0.9) * 2.2  # bounded by 1/(1−μ) scale


# ----------------------- DP invariants under sharding -----------------------
#
# cohort_sum's (n_blocks, num_pods) pair is the aggregation-topology knob
# (the sharded engine's per-shard partials are exactly its blocks, and the
# engine's cross-pod fold is exactly fold_pods' two-level tree), so sweeping
# them here is sweeping the whole 2-D (pod, data) topology family — without
# needing multiple devices.


def _clipped_cohort(seed, P, clip, scale=5.0):
    """Stacked per-client updates, each clipped to norm ≤ clip."""
    keys = jax.random.split(jax.random.PRNGKey(seed), P)
    stack = jax.vmap(lambda k: _tree(k, scale))(keys)
    clipped, _, _ = jax.vmap(
        lambda u: clip_by_global_norm(u, clip))(stack)
    return clipped


@pytest.mark.parametrize("num_pods", [1, 2, 4])
@pytest.mark.parametrize("n_blocks", [1, 2, 4, 8, 16])
@pytest.mark.parametrize("seed", [0, 11])
def test_single_device_sensitivity_bounded_any_topology(n_blocks, num_pods,
                                                        seed):
    """Removing any single device from the round moves the *averaged*
    update by at most S/(qN), whatever block/shard/pod structure aggregates
    the clipped sum — the clipped-sum sensitivity bound the accountant's ε
    depends on survives every aggregation topology [MRTZ17]."""
    if n_blocks % num_pods:
        pytest.skip("pods must divide the block count (layout invariant)")
    P, qN, clip = 16, 12, 0.8
    clipped = _clipped_cohort(seed, P, clip)
    mask = (jnp.arange(P) < qN).astype(jnp.float32)
    base = cohort_sum(clipped, mask, n_blocks, num_pods)
    for slot in (0, 5, qN - 1):
        drop = mask.at[slot].set(0.0)
        neigh = cohort_sum(clipped, drop, n_blocks, num_pods)
        diff = jax.tree_util.tree_map(lambda a, b: (a - b) / qN, base, neigh)
        sens = float(tree_global_norm(diff))
        assert sens <= clip / qN * (1 + 1e-4), (n_blocks, slot, sens)
        # and the removed contribution is that device's clipped update
        # exactly (float-exact: masked adds are adds of true zeros)
        dev = jax.tree_util.tree_map(lambda l: l[slot] / qN, clipped)
        np.testing.assert_allclose(sens, float(tree_global_norm(dev)),
                                   rtol=1e-5)


@pytest.mark.parametrize("num_pods", [1, 2])
@pytest.mark.parametrize("n_blocks", [1, 2, 4, 8])
def test_poisson_mask_zeroes_excluded_slots(n_blocks, num_pods):
    """Slots the Poisson draw leaves empty (and padded slots of a ragged
    buffer) contribute *exactly* zero to the aggregated update — even if
    the buffer's excluded rows hold garbage, because 0·x and x+0 are exact
    in IEEE float. This is what makes the fixed-shape buffer a faithful
    implementation of variable-size rounds."""
    if n_blocks % num_pods:
        pytest.skip("pods must divide the block count (layout invariant)")
    N, buffer = 64, canon_pad(24, n_blocks)
    avail = jnp.ones((N,), bool)
    ids, slot_mask, took = poisson_select(jax.random.PRNGKey(3), 0.25,
                                          avail, buffer)
    assert int(slot_mask.sum()) == int(took.sum())  # buffer ample: no drops
    assert not bool(slot_mask[-1])                  # some excluded slots
    clean = _clipped_cohort(7, buffer, 0.8)
    m = slot_mask.astype(jnp.float32)
    poisoned = jax.tree_util.tree_map(
        lambda l: jnp.where(m.reshape((-1,) + (1,) * (l.ndim - 1)) > 0,
                            l, 1e30), clean)
    zeroed = jax.tree_util.tree_map(
        lambda l: l * m.reshape((-1,) + (1,) * (l.ndim - 1)), clean)
    a = cohort_sum(poisoned, slot_mask, n_blocks, num_pods)
    b = cohort_sum(zeroed, slot_mask, n_blocks, num_pods)
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ------------------- report-goal calibration (production fault protocol)


def test_sigma_calibrated_to_report_goal_not_realized_count():
    """Under the fault protocol `finalize_round` gets the *report goal* as
    the round size: σ = z·S/goal regardless of how many survivors actually
    folded, and the released mean is clipped_sum/goal — so removing one
    accepted client moves the release by at most S/goal, the sensitivity
    the accountant's ε assumes. Dividing by a realized count (goal ± luck)
    would make both σ and the sensitivity data-dependent — exactly what the
    report-goal calibration forbids."""
    goal, realized, clip, z = 10, 14, 0.8, 0.7
    dp = DPConfig(clip_norm=clip, noise_multiplier=z,
                  clients_per_round=goal)
    clipped = _clipped_cohort(3, realized, clip)
    mask = jnp.ones((realized,), jnp.float32)
    total = jax.tree_util.tree_map(
        lambda l: jnp.sum(l * mask.reshape((-1,) + (1,) * (l.ndim - 1)),
                          axis=0), clipped)
    # σ — identical whatever the realized count, because only `goal` enters
    _, stats = finalize_round(total, goal, jax.random.PRNGKey(0), dp)
    assert abs(float(stats.noise_std) - z * clip / goal) < 1e-8
    # released mean is sum/goal: drop any one accepted client ⇒ the release
    # moves by exactly ‖that client's clipped update‖/goal ≤ S/goal
    dp0 = DPConfig(clip_norm=clip, noise_multiplier=0.0,
                   clients_per_round=goal)
    base, _ = finalize_round(total, goal, jax.random.PRNGKey(0), dp0)
    for slot in (0, 7, realized - 1):
        drop = mask.at[slot].set(0.0)
        t2 = jax.tree_util.tree_map(
            lambda l: jnp.sum(l * drop.reshape((-1,) + (1,) * (l.ndim - 1)),
                              axis=0), clipped)
        neigh, _ = finalize_round(t2, goal, jax.random.PRNGKey(0), dp0)
        diff = jax.tree_util.tree_map(lambda a, b: a - b, base, neigh)
        sens = float(tree_global_norm(diff))
        assert sens <= clip / goal * (1 + 1e-4)
        dev = jax.tree_util.tree_map(lambda l: l[slot] / goal, clipped)
        np.testing.assert_allclose(sens, float(tree_global_norm(dev)),
                                   rtol=1e-5)


@pytest.mark.parametrize("sampling", ["fixed", "poisson"])
def test_participation_identical_across_backends_and_shards(sampling):
    """Per-device participation counts — the quantity per-user privacy
    accounting reads — are identical across the engine's compiled scan, its
    per-round reference loop, and every available shard count."""
    from repro.configs import ClientConfig, get_config
    from repro.data.corpus import BigramCorpus
    from repro.data.federated import FederatedDataset
    from repro.fl.engine import SimEngine
    from repro.models import build

    cfg = get_config("gboard-cifg-lstm").with_(vocab=64, d_model=8, d_ff=16)
    model = build(cfg)
    ds = FederatedDataset(BigramCorpus(vocab_size=64, seed=0), n_users=40,
                          seq_len=8, sentences_per_user=6)
    dp = DPConfig(clients_per_round=8, noise_multiplier=0.0, clip_norm=0.8,
                  server_opt="sgd", server_lr=0.1, sampling=sampling)
    cl = ClientConfig(local_epochs=1, batch_size=4, lr=0.3)
    shard_counts = [s for s in (1, 2, 8) if s <= len(jax.devices())]
    counts = {}
    for s in shard_counts:
        eng = SimEngine(model, ds.to_device_arrays(), dp, cl,
                        n_local_batches=2, availability=1.0,
                        rounds_per_call=2, num_shards=s)
        for runner in ("run", "run_python"):
            state = eng.init_state(model.init(jax.random.PRNGKey(1)),
                                   seed=0)
            state, _ = getattr(eng, runner)(state, 4)
            counts[(s, runner)] = np.asarray(state.participation)
    ref = counts[(1, "run")]
    assert ref.sum() > 0
    for key, c in counts.items():
        np.testing.assert_array_equal(c, ref, err_msg=str(key))
