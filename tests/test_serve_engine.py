"""Continuous-batching serving engine: token-for-token parity with the
single-request reference path (greedy + seeded temperature, interleaved
admission/eviction, across checkpoint hot-swap boundaries), top-k candidate
shape/ordering, TTL eviction, queueing/slot reuse, and the per-row cache
layout contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build
from repro.serve import (NwpRequest, ServeEngine, reference_generate,
                         validate_cache_layout)
from repro.train import checkpoint


@pytest.fixture(scope="module")
def lstm():
    cfg = get_config("gboard-cifg-lstm").with_(vocab=300, d_model=32,
                                               d_ff=64)
    model = build(cfg)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def params_b(lstm):
    model, _ = lstm
    return model.init(jax.random.PRNGKey(42))


def _requests(rng, n, vocab=300, temperature=0.0, seed0=100):
    reqs = []
    for i in range(n):
        prompt = tuple(int(t) for t in
                       rng.integers(4, vocab, size=int(rng.integers(2, 7))))
        reqs.append(NwpRequest(prompt=prompt,
                               steps=int(rng.integers(1, 7)),
                               temperature=temperature,
                               seed=seed0 + i if temperature > 0 else None))
    return reqs


def _assert_matches_reference(model, params, engine, reqs, sids, top_k=3):
    for req, sid in zip(reqs, sids):
        res = engine.result(sid)
        toks, cands = reference_generate(
            model, params, req.prompt, req.steps,
            temperature=req.temperature, seed=req.seed, top_k=top_k)
        assert res.tokens == toks, sid
        np.testing.assert_array_equal(res.candidates, cands)


def test_engine_matches_reference_greedy(lstm):
    """Slots << sessions: queueing + slot reuse must not change any
    session's tokens or candidate strip."""
    model, params = lstm
    eng = ServeEngine(model, params, max_slots=2, top_k=3)
    reqs = _requests(np.random.default_rng(0), 6)
    sids = [eng.submit(r) for r in reqs]
    res = eng.run()
    assert len(res) == 6
    assert all(r.status == "done" for r in res.values())
    _assert_matches_reference(model, params, eng, reqs, sids)


def test_engine_matches_reference_temperature(lstm):
    """Seeded-temperature sessions: per-session streams are independent of
    batch composition, deterministic across runs, and distinct across
    seeds."""
    model, params = lstm
    reqs = _requests(np.random.default_rng(1), 5, temperature=0.8)
    outs = []
    for _ in range(2):  # engine determinism: identical second run
        eng = ServeEngine(model, params, max_slots=3, top_k=3)
        sids = [eng.submit(r) for r in reqs]
        eng.run()
        _assert_matches_reference(model, params, eng, reqs, sids)
        outs.append([eng.result(s).tokens for s in sids])
    assert outs[0] == outs[1]

    # same prompt, different seeds → different streams (overwhelmingly)
    eng = ServeEngine(model, params, max_slots=2, top_k=3)
    a = eng.submit(NwpRequest(prompt=(2, 5, 9), steps=8, temperature=0.9,
                              seed=7))
    b = eng.submit(NwpRequest(prompt=(2, 5, 9), steps=8, temperature=0.9,
                              seed=8))
    eng.run()
    assert eng.result(a).tokens != eng.result(b).tokens


def test_interleaved_admission_parity(lstm):
    """Sessions submitted mid-flight (while others are at different decode
    depths) still match the reference exactly — admission timing is not
    allowed to leak into the tokens."""
    model, params = lstm
    eng = ServeEngine(model, params, max_slots=3, top_k=3)
    rng = np.random.default_rng(2)
    first = _requests(rng, 3, temperature=0.6, seed0=200)
    sids = [eng.submit(r) for r in first]
    eng.step()
    eng.step()
    late = _requests(rng, 4, temperature=0.6, seed0=300)
    sids += [eng.submit(r) for r in late]
    eng.step()
    more = _requests(rng, 2)
    sids += [eng.submit(r) for r in more]
    eng.run()
    _assert_matches_reference(model, params, eng, first + late + more, sids)


def test_fifo_admission_and_slot_reuse(lstm):
    model, params = lstm
    eng = ServeEngine(model, params, max_slots=1, top_k=2)
    reqs = [NwpRequest(prompt=(2, 10 + i), steps=3) for i in range(4)]
    sids = [eng.submit(r) for r in reqs]
    eng.run()
    admits = [eng.result(s).admit_tick for s in sids]
    assert admits == sorted(admits)  # FIFO through the single slot
    assert all(eng.result(s).status == "done" for s in sids)
    _assert_matches_reference(model, params, eng, reqs, sids, top_k=2)


def test_topk_candidates_shape_and_ordering(lstm):
    model, params = lstm
    eng = ServeEngine(model, params, max_slots=2, top_k=4)
    sid = eng.submit(NwpRequest(prompt=(2, 5, 9), steps=5))
    narrow = eng.submit(NwpRequest(prompt=(2, 5, 9), steps=5, top_k=2))
    eng.run()
    res = eng.result(sid)
    assert res.candidates.shape == (5, 4)
    # greedy token is always candidate 0; candidates are rank-ordered by
    # logit (reference comparison pins the full ordering)
    np.testing.assert_array_equal(res.candidates[:, 0],
                                  np.asarray(res.tokens))
    assert all(len(set(row)) == 4 for row in res.candidates)
    _, ref_cands = reference_generate(model, params, (2, 5, 9), 5, top_k=4)
    np.testing.assert_array_equal(res.candidates, ref_cands)
    # per-request top_k narrows the strip without recompiling the engine
    assert eng.result(narrow).candidates.shape == (5, 2)
    np.testing.assert_array_equal(eng.result(narrow).candidates,
                                  ref_cands[:, :2])


def test_hot_swap_atomicity_and_parity(lstm, params_b):
    """Promote new params with sessions in flight: nobody dropped, each
    session's version trail is monotone with at most one transition, and
    tokens match a reference that swaps checkpoints at the same index."""
    model, params = lstm
    eng = ServeEngine(model, params, max_slots=4, top_k=3)
    reqs = [NwpRequest(prompt=(2, 5, 9 + i), steps=8,
                       temperature=0.7 if i % 2 else 0.0,
                       seed=50 + i if i % 2 else None) for i in range(4)]
    sids = [eng.submit(r) for r in reqs]
    for _ in range(3):
        eng.step()
    assert eng.active_sessions == 4
    assert eng.swap_params(params_b) == 1
    post = NwpRequest(prompt=(2, 77), steps=4)
    post_sid = eng.submit(post)
    eng.run()

    for req, sid in zip(reqs, sids):
        res = eng.result(sid)
        assert res.status == "done"  # zero dropped across the swap
        vs = res.params_versions
        assert list(vs) == sorted(vs) and set(vs) <= {0, 1}
        assert vs[0] == 0 and vs[-1] == 1  # swap landed mid-session
        swap_at = vs.index(1)
        toks, cands = reference_generate(
            model, params, req.prompt, req.steps,
            temperature=req.temperature, seed=req.seed, top_k=3,
            swaps=[(swap_at, params_b)])
        assert res.tokens == toks
        np.testing.assert_array_equal(res.candidates, cands)

    # a session admitted after the swap is pure-v1, prefill included
    res = eng.result(post_sid)
    assert set(res.params_versions) == {1}
    toks, _ = reference_generate(model, params, post.prompt, post.steps,
                                 swaps=[(0, params_b)])
    assert res.tokens == toks


def test_hot_swap_from_checkpoint_file(tmp_path, lstm, params_b):
    """The production promotion path: a freshly trained round lands as a
    checkpoint file and is swapped in without dropping sessions."""
    model, params = lstm
    ck = tmp_path / "round_next.msgpack"
    checkpoint.save(ck, params_b, meta={"arch": model.cfg.name})
    eng = ServeEngine(model, params, max_slots=2, top_k=3)
    sid = eng.submit(NwpRequest(prompt=(2, 5, 9), steps=6))
    eng.step()
    assert eng.load_checkpoint(ck) == 1
    eng.run()
    res = eng.result(sid)
    assert res.status == "done"
    swap_at = res.params_versions.index(1)
    toks, _ = reference_generate(model, params, (2, 5, 9), 6,
                                 swaps=[(swap_at, params_b)])
    assert res.tokens == toks


def test_ttl_eviction_frees_slot(lstm):
    """A session that exceeds its tick budget is evicted with its partial
    output (a reference prefix), and its slot is handed to the queue."""
    model, params = lstm
    eng = ServeEngine(model, params, max_slots=1, top_k=3)
    hog = eng.submit(NwpRequest(prompt=(2, 5), steps=50, ttl_ticks=3))
    nxt = eng.submit(NwpRequest(prompt=(2, 9), steps=2))
    eng.run()
    res = eng.result(hog)
    assert res.status == "evicted"
    assert len(res.tokens) == 4  # token0 at admission + 3 decode ticks
    ref_toks, _ = reference_generate(model, params, (2, 5), 4)
    assert res.tokens == ref_toks
    assert eng.result(nxt).status == "done"
    assert len(eng.result(nxt).tokens) == 2


def test_steps0_completes_immediately(lstm):
    model, params = lstm
    eng = ServeEngine(model, params, max_slots=2, top_k=3)
    sid = eng.submit(NwpRequest(prompt=(2, 5, 9), steps=0))
    res = eng.result(sid)
    assert res.status == "done" and res.tokens == ()
    assert res.candidates.shape == (0, 3)
    assert res.sequence == (2, 5, 9)  # exactly the prompt
    assert eng.in_flight == 0  # never took a slot or a tick


def test_submit_validation(lstm):
    model, params = lstm
    eng = ServeEngine(model, params, max_slots=2, top_k=3)
    with pytest.raises(ValueError, match="seed"):
        eng.submit(NwpRequest(prompt=(2, 5), steps=3, temperature=0.8))
    with pytest.raises(ValueError, match="steps"):
        eng.submit(NwpRequest(prompt=(2, 5), steps=-1))
    with pytest.raises(ValueError, match="prompt tokens"):
        eng.submit(NwpRequest(prompt=(2, 999), steps=1))
    with pytest.raises(ValueError, match="top_k"):
        eng.submit(NwpRequest(prompt=(2, 5), steps=1, top_k=7))
    sid = eng.submit(NwpRequest(prompt=(2, 5), steps=0, session_id="dup"))
    with pytest.raises(ValueError, match="duplicate"):
        eng.submit(NwpRequest(prompt=(2, 5), steps=1, session_id="dup"))
    assert sid == "dup"


def test_cache_layout_contract_rejected(lstm):
    """Ring-buffer KV models share a scalar position across the batch —
    the engine must refuse them with a clear error, not corrupt slots."""
    cfg = get_config("granite-3-2b").reduced()
    model = build(cfg)
    with pytest.raises(ValueError, match="continuous-batching"):
        validate_cache_layout(model, max_slots=4, max_len=16)
    with pytest.raises(ValueError, match="per-row"):
        ServeEngine(model, {}, max_slots=4)
    # the paper's model passes the same validation the engine runs
    lstm_model, _ = lstm
    cache = validate_cache_layout(lstm_model, max_slots=4, max_len=16)
    assert all(np.shape(leaf)[0] == 4
               for leaf in jax.tree_util.tree_leaves(cache))


def test_engine_constructor_validation(lstm):
    model, params = lstm
    with pytest.raises(ValueError, match="max_slots"):
        ServeEngine(model, params, max_slots=0)
    with pytest.raises(ValueError, match="top_k"):
        ServeEngine(model, params, max_slots=2, top_k=0)
