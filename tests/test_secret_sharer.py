"""Secret Sharer measurement framework: a model that memorized its canary
must rank ~0 / be beam-extractable; a clean model must not."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.secret_sharer import (Canary, beam_search, canary_extracted,
                                      log_perplexity, make_canaries,
                                      random_sampling_rank)
from repro.models import build

VOCAB = 256


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("gboard-cifg-lstm").with_(vocab=VOCAB, d_model=32,
                                               d_ff=64)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _memorize(model, params, canary, steps=300, lr=0.5):
    toks = jnp.asarray(canary.tokens, jnp.int32)[None, :]
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    loss_g = jax.jit(jax.value_and_grad(model.loss_fn))
    for _ in range(steps):
        loss, g = loss_g(params, batch)
        params = jax.tree_util.tree_map(lambda p, gr: p - lr * gr, params, g)
    return params


def test_make_canaries_grid():
    cs = make_canaries(jax.random.PRNGKey(1), vocab=VOCAB)
    assert len(cs) == 27
    assert all(len(c.tokens) == 5 for c in cs)
    assert all(0 <= t < VOCAB for c in cs for t in c.tokens)
    assert sorted({(c.n_u, c.n_e) for c in cs}) == sorted(
        [(1, 1), (1, 14), (1, 200), (4, 1), (4, 14), (4, 200),
         (16, 1), (16, 14), (16, 200)])


def test_log_perplexity_orders_memorized(tiny_model):
    cfg, model, params = tiny_model
    canary = Canary((5, 9, 13, 17, 21), 1, 1)
    trained = _memorize(model, params, canary)
    seq = np.asarray([canary.tokens], np.int32)
    lp_before = log_perplexity(model, params, seq)[0]
    lp_after = log_perplexity(model, trained, seq)[0]
    assert lp_after < lp_before - 2.0


def test_random_sampling_rank_separates(tiny_model):
    cfg, model, params = tiny_model
    canary = Canary((5, 9, 13, 17, 21), 1, 1)
    trained = _memorize(model, params, canary)
    key = jax.random.PRNGKey(3)
    rank_clean = random_sampling_rank(model, params, canary, key,
                                      n_samples=2000, batch_size=500)
    rank_mem = random_sampling_rank(model, trained, canary, key,
                                    n_samples=2000, batch_size=500)
    assert rank_mem < 10
    assert rank_clean > 100


def test_beam_search_extracts_memorized(tiny_model):
    cfg, model, params = tiny_model
    canary = Canary((5, 9, 13, 17, 21), 1, 1)
    trained = _memorize(model, params, canary)
    assert canary_extracted(model, trained, canary)
    assert not canary_extracted(model, params, canary)


def test_beam_search_width(tiny_model):
    cfg, model, params = tiny_model
    tops = beam_search(model, params, (1, 2), total_len=5, width=5)
    assert len(tops) == 5
    assert all(len(t) == 5 for t in tops)
    assert len(set(tops)) == 5
