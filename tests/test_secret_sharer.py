"""Secret Sharer measurement framework: a model that memorized its canary
must rank ~0 / be beam-extractable; a clean model must not."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.secret_sharer import (PREFIX_LEN, Canary, beam_search,
                                      canary_extracted, canary_matrix,
                                      log_perplexity, make_canaries,
                                      random_sampling_rank,
                                      random_sampling_ranks, score_canaries)
from repro.models import build

VOCAB = 256


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("gboard-cifg-lstm").with_(vocab=VOCAB, d_model=32,
                                               d_ff=64)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _memorize(model, params, canary, steps=300, lr=0.5):
    toks = jnp.asarray(canary.tokens, jnp.int32)[None, :]
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    loss_g = jax.jit(jax.value_and_grad(model.loss_fn))
    for _ in range(steps):
        loss, g = loss_g(params, batch)
        params = jax.tree_util.tree_map(lambda p, gr: p - lr * gr, params, g)
    return params


def test_make_canaries_grid():
    cs = make_canaries(jax.random.PRNGKey(1), vocab=VOCAB)
    assert len(cs) == 27
    assert all(len(c.tokens) == 5 for c in cs)
    assert all(0 <= t < VOCAB for c in cs for t in c.tokens)
    assert sorted({(c.n_u, c.n_e) for c in cs}) == sorted(
        [(1, 1), (1, 14), (1, 200), (4, 1), (4, 14), (4, 200),
         (16, 1), (16, 14), (16, 200)])


def test_make_canaries_prefixes_never_collide():
    """Two canaries sharing a beam-search prefix would make per-canary
    extraction ill-defined — draws are rejected/redrawn. Tiny vocab forces
    actual collisions, so the redraw path is exercised."""
    cs = make_canaries(jax.random.PRNGKey(0), vocab=3,
                       grid=[(1, 1)], per_config=8)
    prefixes = [c.prefix for c in cs]
    assert len(set(prefixes)) == len(prefixes) == 8
    assert all(0 <= t < 3 for c in cs for t in c.tokens)


def test_make_canaries_impossible_grid_raises():
    with pytest.raises(ValueError, match="distinct"):
        make_canaries(jax.random.PRNGKey(0), vocab=3,
                      grid=[(1, 1)], per_config=10)  # only 9 prefixes exist


def test_score_canaries_matches_log_perplexity(tiny_model):
    """The vmapped in-scan kernel and the chunked host scorer are the same
    computation."""
    cfg, model, params = tiny_model
    cs = make_canaries(jax.random.PRNGKey(2), vocab=VOCAB,
                       grid=[(1, 1), (4, 14)], per_config=2)
    toks = canary_matrix(cs)
    batched = np.asarray(jax.jit(
        lambda p, t: score_canaries(model, p, t))(params, toks))
    looped = log_perplexity(model, params, toks, batch_size=toks.shape[0])
    np.testing.assert_allclose(batched, looped, rtol=1e-6)
    assert batched.shape == (len(cs),)


def test_log_perplexity_orders_memorized(tiny_model):
    cfg, model, params = tiny_model
    canary = Canary((5, 9, 13, 17, 21), 1, 1)
    trained = _memorize(model, params, canary)
    seq = np.asarray([canary.tokens], np.int32)
    lp_before = log_perplexity(model, params, seq)[0]
    lp_after = log_perplexity(model, trained, seq)[0]
    assert lp_after < lp_before - 2.0


def test_random_sampling_rank_separates(tiny_model):
    cfg, model, params = tiny_model
    canary = Canary((5, 9, 13, 17, 21), 1, 1)
    trained = _memorize(model, params, canary)
    key = jax.random.PRNGKey(3)
    rank_clean = random_sampling_rank(model, params, canary, key,
                                      n_samples=2000, batch_size=500)
    rank_mem = random_sampling_rank(model, trained, canary, key,
                                    n_samples=2000, batch_size=500)
    assert rank_mem < 10
    assert rank_clean > 100


def test_random_sampling_ranks_batched_orders(tiny_model):
    """Batched multi-canary ranking: the memorized canary ranks far below
    the unseen one against the same shared continuation pool, and the
    single-canary wrapper agrees with the batched kernel."""
    cfg, model, params = tiny_model
    memorized = Canary((5, 9, 13, 17, 21), 1, 1)
    unseen = Canary((7, 11, 15, 19, 23), 1, 1)
    trained = _memorize(model, params, memorized)
    key = jax.random.PRNGKey(3)
    ranks = random_sampling_ranks(model, trained, [memorized, unseen], key,
                                  n_samples=2000, batch_size=500)
    assert ranks.shape == (2,)
    assert ranks[0] < 10
    assert ranks[1] > 100
    assert random_sampling_rank(model, trained, memorized, key,
                                n_samples=2000, batch_size=500) == ranks[0]


def test_beam_search_extracts_memorized(tiny_model):
    cfg, model, params = tiny_model
    canary = Canary((5, 9, 13, 17, 21), 1, 1)
    trained = _memorize(model, params, canary)
    assert canary_extracted(model, trained, canary)
    assert not canary_extracted(model, params, canary)


def test_beam_search_width(tiny_model):
    cfg, model, params = tiny_model
    tops = beam_search(model, params, (1, 2), total_len=5, width=5)
    assert len(tops) == 5
    assert all(len(t) == 5 for t in tops)
    assert len(set(tops)) == 5
