"""Compiled simulation engine ↔ reference-loop parity.

`SimEngine.run` (lax.scan, K rounds per jit) and `SimEngine.run_python`
(one jit entry per round) trace the identical round body from the same PRNG
stream, so with a shared seed they must sample the same cohorts and produce
the same histories. With zero noise the first round must be bit-exact.

NOTE on donation: `run` donates its input state buffers, so every entry
point gets a freshly built state.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ClientConfig, DPConfig, get_config
from repro.data.corpus import BigramCorpus
from repro.data.federated import FederatedDataset
from repro.fl.engine import SimEngine
from repro.fl.population import PopulationSim
from repro.fl.round import FederatedTrainer
from repro.models import build

VOCAB = 300
ROUNDS = 8


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("gboard-cifg-lstm").with_(vocab=VOCAB, d_model=24,
                                               d_ff=48)
    model = build(cfg)
    corpus = BigramCorpus(vocab_size=VOCAB, seed=0)
    ds = FederatedDataset(corpus, n_users=80, seq_len=16,
                          sentences_per_user=20)
    return cfg, model, corpus, ds


def _engine(model, ds, *, noise=0.0, rounds_per_call=4):
    dp = DPConfig(clients_per_round=12, noise_multiplier=noise,
                  clip_norm=0.8, server_opt="momentum", server_lr=0.5,
                  server_momentum=0.9)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    return SimEngine(model, ds.to_device_arrays(), dp, cl,
                     n_local_batches=2, availability=0.5,
                     rounds_per_call=rounds_per_call)


def _init(eng, model, seed=0):
    return eng.init_state(model.init(jax.random.PRNGKey(1)), seed=seed)


def _max_leaf_diff(a, b):
    d = jax.tree_util.tree_map(
        lambda x, y: float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                           - y.astype(jnp.float32)))), a, b)
    return max(jax.tree_util.tree_leaves(d))


def test_zero_noise_one_round_bit_exact(setup):
    """Scan-of-1 vs direct jit call: identical cohort, identical params."""
    _, model, _, ds = setup
    eng = _engine(model, ds, noise=0.0)
    sa, ha = eng.run(_init(eng, model), 1)
    sb, hb = eng.run_python(_init(eng, model), 1)
    assert _max_leaf_diff(sa.params, sb.params) == 0.0
    assert float(ha["loss"][0]) == float(hb["loss"][0])
    np.testing.assert_array_equal(np.asarray(sa.participation),
                                  np.asarray(sb.participation))


def test_trajectory_parity_and_participation(setup):
    """Same seed ⇒ same loss trajectory (within float tolerance across the
    two compilation strategies) and identical participation counts."""
    _, model, _, ds = setup
    eng = _engine(model, ds, noise=0.3, rounds_per_call=4)
    sa, ha = eng.run(_init(eng, model), ROUNDS)       # 4+4+2 chunked scan
    sb, hb = eng.run_python(_init(eng, model), ROUNDS)
    np.testing.assert_allclose(ha["loss"], hb["loss"], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ha["frac_clipped"], hb["frac_clipped"],
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(sa.participation),
                                  np.asarray(sb.participation))
    assert int(np.asarray(sa.participation).sum()) == ROUNDS * eng.cohort
    assert _max_leaf_diff(sa.params, sb.params) < 1e-4
    # history schema + σ = z·S/qN actually applied every round
    assert set(ha) == {"loss", "mean_update_norm", "frac_clipped",
                       "noise_std", "n_clients"}
    np.testing.assert_array_equal(ha["n_clients"], 12)
    np.testing.assert_allclose(ha["noise_std"], 0.3 * 0.8 / 12, rtol=1e-6)
    assert np.all(np.isfinite(ha["loss"]))


def test_trainer_backends_parity(setup):
    """FederatedTrainer(backend="engine") ≡ backend="engine_python" under a
    shared seed, and both produce a decreasing loss like the host loop."""
    _, model, _, ds = setup
    dp = DPConfig(clients_per_round=12, noise_multiplier=0.3, clip_norm=0.8,
                  server_opt="momentum", server_lr=0.5, server_momentum=0.9)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    hists = {}
    for backend in ("engine", "engine_python", "host"):
        # availability high enough that the host loop's check-in pool always
        # covers the fixed cohort (the engine's cohort is fixed by shape)
        pop = PopulationSim(len(ds.users), availability=0.6, seed=0)
        tr = FederatedTrainer(model, ds, dp, cl, pop=pop, n_local_batches=2,
                              seed=0, backend=backend, rounds_per_call=5)
        tr.train(ROUNDS)
        assert tr.accountant.rounds == ROUNDS
        assert all(r["n_clients"] == 12 for r in tr.state.history)
        hists[backend] = tr
    a, b = hists["engine"], hists["engine_python"]
    np.testing.assert_allclose([r["loss"] for r in a.state.history],
                               [r["loss"] for r in b.state.history],
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(a.participation, b.participation)
    # the independent host reference also learns from the same start
    for tr in hists.values():
        h = tr.state.history
        assert h[-1]["loss"] < h[0]["loss"]
    assert abs(a.state.history[-1]["loss"]
               - hists["host"].state.history[-1]["loss"]) < 1.0


def test_trainer_poisson_backends(setup):
    """FederatedTrainer(sampling="poisson") works on both backends: host
    rounds shrink/grow with the draw, the engine's history reports realized
    sizes, σ is constant at z·S/qN, and the accountant gets the matching
    subsampling bound."""
    _, model, _, ds = setup
    dp = DPConfig(clients_per_round=12, noise_multiplier=0.3, clip_norm=0.8,
                  server_opt="momentum", server_lr=0.5, server_momentum=0.9,
                  sampling="poisson")
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    sizes = {}
    for backend in ("engine", "host"):
        pop = PopulationSim(len(ds.users), availability=1.0, seed=0)
        tr = FederatedTrainer(model, ds, dp, cl, pop=pop, n_local_batches=2,
                              seed=0, backend=backend, rounds_per_call=3)
        assert tr.accountant.sampling == "poisson"
        tr.train(3)
        recs = tr.state.history
        assert all(np.isfinite(r["loss"]) for r in recs)
        np.testing.assert_allclose([r["noise_std"] for r in recs],
                                   0.3 * 0.8 / 12, rtol=1e-6)
        sizes[backend] = [r["n_clients"] for r in recs]
        assert int(tr.participation.sum()) == sum(sizes[backend])
    # Bernoulli(q) round composition: realized sizes are not the constant qN
    assert any(n != 12 for n in sizes["engine"] + sizes["host"])


def test_engine_pace_steering_suppresses_repeats(setup):
    """With full availability and a long cooldown, a cohort participating in
    round r is (almost surely) excluded for the following rounds."""
    _, model, _, ds = setup
    dp = DPConfig(clients_per_round=12, noise_multiplier=0.0, clip_norm=0.8,
                  server_opt="sgd", server_lr=0.1)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    eng = SimEngine(model, ds.to_device_arrays(), dp, cl, n_local_batches=2,
                    availability=1.0, pace_cooldown=10 ** 6,
                    pace_penalty=1e-9, rounds_per_call=4)
    s, _ = eng.run(_init(eng, model), 4)
    # 4 rounds × 12 distinct clients: nobody repeats while cooling down
    assert int(np.asarray(s.participation).max()) == 1
    assert int(np.asarray(s.participation).sum()) == 4 * 12


def test_eval_hook_masking_and_parity(setup):
    """eval_fn runs inside the scan on post-update params every eval_every
    rounds; other rounds carry zeros, and the compiled scan and the
    per-round-jit reference produce identical stacked outputs."""
    _, model, _, ds = setup

    def eval_fn(params, round_idx):
        flat = jnp.concatenate([jnp.ravel(l) for l in
                                jax.tree_util.tree_leaves(params)])
        return {"pnorm": jnp.linalg.norm(flat),
                "round": round_idx.astype(jnp.int32)}

    dp = DPConfig(clients_per_round=12, noise_multiplier=0.3, clip_norm=0.8,
                  server_opt="momentum", server_lr=0.5, server_momentum=0.9)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    eng = SimEngine(model, ds.to_device_arrays(), dp, cl, n_local_batches=2,
                    availability=0.5, rounds_per_call=4,
                    eval_fn=eval_fn, eval_every=3)
    sa, ha = eng.run(_init(eng, model), 6)
    sb, hb = eng.run_python(_init(eng, model), 6)
    # mask: rounds 3 and 6 (1-indexed) are evaluated
    np.testing.assert_array_equal(
        ha["eval_mask"], [False, False, True, False, False, True])
    np.testing.assert_array_equal(ha["eval_mask"], hb["eval_mask"])
    np.testing.assert_allclose(ha["eval"]["pnorm"], hb["eval"]["pnorm"],
                               rtol=1e-6)
    # masked rounds carry zeros; evaluated rounds a real (positive) norm
    assert np.all(ha["eval"]["pnorm"][~ha["eval_mask"]] == 0.0)
    assert np.all(ha["eval"]["pnorm"][ha["eval_mask"]] > 0.0)
    # eval_fn sees the 0-based index of the round it closes
    np.testing.assert_array_equal(ha["eval"]["round"], [0, 0, 2, 0, 0, 5])


def test_in_scan_canary_hook_matches_posthoc_scoring(setup):
    """Zero noise: the in-scan canary log-perplexity hook must equal host
    post-hoc scoring of the final params bit-exactly (the engine is the
    measurement substrate, not an approximation of it)."""
    from repro.core.secret_sharer import (canary_eval_fn, canary_matrix,
                                          log_perplexity, make_canaries)
    _, model, _, ds = setup
    canaries = make_canaries(jax.random.PRNGKey(5), vocab=VOCAB,
                             grid=[(1, 4), (2, 6)], per_config=1)
    ds_c = FederatedDataset(ds.corpus, n_users=40, seq_len=16,
                            sentences_per_user=20)
    ds_c.inject_canaries(canaries)
    dp = DPConfig(clients_per_round=10, noise_multiplier=0.0, clip_norm=0.8,
                  server_opt="momentum", server_lr=0.5, server_momentum=0.9)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    eng = SimEngine(model, ds_c.to_device_arrays(), dp, cl,
                    n_local_batches=2, availability=0.5, rounds_per_call=2,
                    eval_fn=canary_eval_fn(model, canaries), eval_every=2)
    s, h = eng.run(_init(eng, model), 4)
    post = log_perplexity(model, s.params, canary_matrix(canaries),
                          batch_size=len(canaries))
    np.testing.assert_array_equal(h["eval"]["canary_logppl"][-1], post)
    # unevaluated rounds are masked out
    np.testing.assert_array_equal(h["eval_mask"], [False, True, False, True])


def test_poisson_rounds(setup):
    """sampling="poisson": variable-size rounds via the Bernoulli mask —
    scan/per-round parity, realized sizes around qN with σ still calibrated
    to the expected round size, and participation counts consistent with
    the per-round sizes."""
    _, model, _, ds = setup
    dp = DPConfig(clients_per_round=12, noise_multiplier=0.3, clip_norm=0.8,
                  server_opt="momentum", server_lr=0.5, server_momentum=0.9,
                  sampling="poisson")
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    eng = SimEngine(model, ds.to_device_arrays(), dp, cl, n_local_batches=2,
                    availability=1.0, rounds_per_call=4)
    assert eng.sampling == "poisson"        # picked up from DPConfig
    sa, ha = eng.run(_init(eng, model), ROUNDS)
    sb, hb = eng.run_python(_init(eng, model), ROUNDS)
    np.testing.assert_allclose(ha["loss"], hb["loss"], rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(ha["n_clients"], hb["n_clients"])
    np.testing.assert_array_equal(np.asarray(sa.participation),
                                  np.asarray(sb.participation))
    # round sizes vary around qN·availability but stay within the buffer
    assert len(set(ha["n_clients"].tolist())) > 1
    assert np.all(ha["n_clients"] <= eng.buffer)
    assert int(np.asarray(sa.participation).sum()) == int(
        ha["n_clients"].sum())
    # σ = z·S/qN against the *expected* round size, not the realized one
    np.testing.assert_allclose(ha["noise_std"], 0.3 * 0.8 / 12, rtol=1e-6)


def test_engine_weight_hook_override(setup):
    """The Pace-Steering weight hook is replaceable: an always-uniform hook
    lets clients repeat even with an infinite cooldown configured."""
    _, model, _, ds = setup
    dp = DPConfig(clients_per_round=30, noise_multiplier=0.0, clip_norm=0.8,
                  server_opt="sgd", server_lr=0.1)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    eng = SimEngine(model, ds.to_device_arrays(), dp, cl, n_local_batches=2,
                    availability=1.0, pace_cooldown=10 ** 6,
                    pace_penalty=1e-9, rounds_per_call=4,
                    weight_fn=lambda last, synth, r: jnp.ones_like(
                        last, jnp.float32))
    s, _ = eng.run(_init(eng, model), 6)
    # 6 rounds × 30 of 90 users sampled uniformly: repeats are certain
    assert int(np.asarray(s.participation).max()) > 1
