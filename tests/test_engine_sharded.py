"""Cohort-sharded engine ↔ unsharded engine parity (the DP-invariant core).

The sharded engine (`SimEngine(num_shards=S)`) must be *the same mechanism*
as the unsharded one, not an approximation: same PRNG stream → identical
cohorts, and — because the clipped sum goes through the canonical block-tree
reduction (`engine.cohort_sum` association) — bit-identical trajectories
for every shard count dividing `engine.CANON_BLOCKS`. That bitwise
invariance is what keeps the clipped-sum sensitivity bound S/(qN), and
hence the accountant's ε, independent of the aggregation topology.

Shard counts above the visible device count are skipped; run the full
{1, 2, 4, 8} grid on CPU with

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest -q tests/test_engine_sharded.py

(the CI ``tier1-sharded`` matrix leg does exactly this).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ClientConfig, DPConfig, get_config
from repro.data.corpus import BigramCorpus
from repro.data.federated import FederatedDataset
from repro.fl.engine import CANON_BLOCKS, SimEngine, canon_pad
from repro.fl.population import PopulationSim
from repro.fl.round import FederatedTrainer
from repro.models import build

VOCAB = 300
ROUNDS = 5
SHARDS = (2, 4, 8)

needs = {s: pytest.mark.skipif(
    len(jax.devices()) < s,
    reason=f"needs {s} devices (XLA_FLAGS="
           f"--xla_force_host_platform_device_count=8)") for s in SHARDS}
shard_params = [pytest.param(s, marks=needs[s]) for s in SHARDS]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("gboard-cifg-lstm").with_(vocab=VOCAB, d_model=24,
                                               d_ff=48)
    model = build(cfg)
    corpus = BigramCorpus(vocab_size=VOCAB, seed=0)
    ds = FederatedDataset(corpus, n_users=80, seq_len=16,
                          sentences_per_user=20)
    return cfg, model, ds


def _run(model, ds, *, num_shards=1, sampling="fixed", noise=0.0,
         cohort=12, rounds=ROUNDS, rounds_per_call=3):
    dp = DPConfig(clients_per_round=cohort, noise_multiplier=noise,
                  clip_norm=0.8, server_opt="momentum", server_lr=0.5,
                  server_momentum=0.9, sampling=sampling)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    eng = SimEngine(model, ds.to_device_arrays(), dp, cl, n_local_batches=2,
                    availability=1.0 if sampling == "poisson" else 0.5,
                    rounds_per_call=rounds_per_call, num_shards=num_shards)
    state = eng.init_state(model.init(jax.random.PRNGKey(1)), seed=0)
    state, hist = eng.run(state, rounds)
    return eng, state, hist


def _max_leaf_diff(a, b):
    d = jax.tree_util.tree_map(
        lambda x, y: float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                           - y.astype(jnp.float32)))), a, b)
    return max(jax.tree_util.tree_leaves(d))


@pytest.fixture(scope="module")
def baselines(setup):
    """num_shards=1 reference runs, one per (sampling, noise) config."""
    _, model, ds = setup
    return {key: _run(model, ds, sampling=key[0], noise=key[1])
            for key in (("fixed", 0.0), ("poisson", 0.0), ("fixed", 0.3))}


@pytest.mark.parametrize("num_shards", shard_params)
@pytest.mark.parametrize("sampling", ["fixed", "poisson"])
def test_sharded_trajectory_parity_bit_exact(setup, baselines, sampling,
                                             num_shards):
    """Zero noise: sharding the cohort axis must not move a single bit —
    identical cohorts (participation), identical realized round sizes, and
    bit-exact params/history against the unsharded engine."""
    _, model, ds = setup
    ref_eng, ref_state, ref_hist = baselines[(sampling, 0.0)]
    eng, state, hist = _run(model, ds, num_shards=num_shards,
                            sampling=sampling)
    assert eng.padded == ref_eng.padded  # same canonical grid, no truncation
    np.testing.assert_array_equal(np.asarray(state.participation),
                                  np.asarray(ref_state.participation))
    np.testing.assert_array_equal(hist["n_clients"], ref_hist["n_clients"])
    np.testing.assert_array_equal(hist["loss"], ref_hist["loss"])
    np.testing.assert_array_equal(hist["mean_update_norm"],
                                  ref_hist["mean_update_norm"])
    assert _max_leaf_diff(state.params, ref_state.params) == 0.0
    assert _max_leaf_diff(state.opt_state, ref_state.opt_state) == 0.0


@pytest.mark.parametrize("num_shards", [pytest.param(8, marks=needs[8])])
def test_sharded_parity_survives_noise(setup, baselines, num_shards):
    """σ > 0: the Gaussian draw comes from the *replicated* PRNG stream
    (drawn once, after the global sum), so even noised trajectories are
    bit-identical across shard counts — σ calibration can't drift with the
    topology."""
    _, model, ds = setup
    _, ref_state, ref_hist = baselines[("fixed", 0.3)]
    _, state, hist = _run(model, ds, num_shards=num_shards, noise=0.3)
    np.testing.assert_array_equal(hist["loss"], ref_hist["loss"])
    np.testing.assert_allclose(hist["noise_std"], 0.3 * 0.8 / 12, rtol=1e-6)
    assert _max_leaf_diff(state.params, ref_state.params) == 0.0
    np.testing.assert_array_equal(np.asarray(state.participation),
                                  np.asarray(ref_state.participation))


@pytest.mark.parametrize("num_shards", [pytest.param(4, marks=needs[4])])
def test_ragged_cohort_pads_not_truncates(setup, num_shards):
    """Regression: cohort=10 doesn't divide 4 shards (or the canonical
    8-block grid) — the buffer must pad to the next canonical multiple and
    keep *all* 10 devices in the round, never drop the remainder."""
    _, model, ds = setup
    eng, state, hist = _run(model, ds, num_shards=num_shards, cohort=10,
                            rounds=3)
    assert eng.padded == canon_pad(10, num_shards) == 16
    assert eng.padded % num_shards == 0
    np.testing.assert_array_equal(hist["n_clients"], 10)  # nobody truncated
    assert int(np.asarray(state.participation).sum()) == 3 * 10
    # padded slots are masked out of the population vectors: only sampled
    # devices have a last_round stamp
    stamped = np.asarray(state.last_round) >= 0
    assert stamped.sum() == np.count_nonzero(np.asarray(state.participation))
    # and the ragged cohort still matches the unsharded engine bitwise
    _, ref_state, ref_hist = _run(model, ds, cohort=10, rounds=3)
    np.testing.assert_array_equal(hist["loss"], ref_hist["loss"])
    assert _max_leaf_diff(state.params, ref_state.params) == 0.0


def test_insufficient_devices_is_a_clear_error(setup):
    """num_shards beyond the visible device count must fail loudly at
    construction, naming the XLA_FLAGS escape hatch — not at first run."""
    _, model, ds = setup
    dp = DPConfig(clients_per_round=12, noise_multiplier=0.0, clip_norm=0.8)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        SimEngine(model, ds.to_device_arrays(), dp, cl,
                  num_shards=len(jax.devices()) + 1)


def test_trainer_num_shards_validation(setup):
    """The trainer forwards num_shards to the engine and rejects it on the
    host backend (which has no cohort axis to shard)."""
    _, model, ds = setup
    dp = DPConfig(clients_per_round=12, noise_multiplier=0.0, clip_norm=0.8)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    with pytest.raises(ValueError, match="engine"):
        FederatedTrainer(model, ds, dp, cl, backend="host", num_shards=2)


def test_model_axis_mesh_config_rejected(setup):
    """The engine shards the cohort over its batch axes only — a MeshConfig
    carrying the model-parallel axis (the full production mesh) must fail
    loudly, not be silently flattened into the cohort layout."""
    from repro.configs.base import MULTI_POD, SINGLE_POD
    _, model, ds = setup
    dp = DPConfig(clients_per_round=12, noise_multiplier=0.0, clip_norm=0.8)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    for cfg in (SINGLE_POD, MULTI_POD):
        with pytest.raises(ValueError, match="batch axes"):
            SimEngine(model, ds.to_device_arrays(), dp, cl, mesh_config=cfg)


@pytest.mark.parametrize("num_shards", [pytest.param(2, marks=needs[2])])
def test_trainer_sharded_matches_unsharded(setup, num_shards):
    """FederatedTrainer(backend="engine", num_shards=S) reproduces the
    unsharded trainer's history and participation exactly at zero noise."""
    _, model, ds = setup
    dp = DPConfig(clients_per_round=12, noise_multiplier=0.0, clip_norm=0.8,
                  server_opt="momentum", server_lr=0.5, server_momentum=0.9)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    runs = {}
    for s in (1, num_shards):
        pop = PopulationSim(len(ds.users), availability=0.6, seed=0)
        tr = FederatedTrainer(model, ds, dp, cl, pop=pop, n_local_batches=2,
                              seed=0, backend="engine", rounds_per_call=3,
                              num_shards=s)
        tr.train(4)
        runs[s] = tr
    a, b = runs[1], runs[num_shards]
    assert [r["loss"] for r in a.state.history] == \
        [r["loss"] for r in b.state.history]
    np.testing.assert_array_equal(a.participation, b.participation)
    assert a.accountant.rounds == b.accountant.rounds == 4


@pytest.mark.slow
@pytest.mark.parametrize("num_shards", [pytest.param(8, marks=needs[8])])
def test_sharded_scan_vs_python_loop(setup, num_shards):
    """The sharded round body is identical under the compiled scan and the
    per-round-jit reference loop (shard_map composes with both)."""
    _, model, ds = setup
    eng, sa, ha = _run(model, ds, num_shards=num_shards, noise=0.3)
    sb_init = eng.init_state(model.init(jax.random.PRNGKey(1)), seed=0)
    sb, hb = eng.run_python(sb_init, ROUNDS)
    np.testing.assert_array_equal(ha["loss"], hb["loss"])
    np.testing.assert_array_equal(np.asarray(sa.participation),
                                  np.asarray(sb.participation))
    assert _max_leaf_diff(sa.params, sb.params) == 0.0


@pytest.mark.slow
@pytest.mark.parametrize("num_shards", [pytest.param(8, marks=needs[8])])
def test_eval_hook_under_sharding(setup, num_shards):
    """In-scan eval hooks run on the replicated post-update params — their
    outputs must match the unsharded engine bitwise too."""
    _, model, ds = setup

    def eval_fn(params, round_idx):
        flat = jnp.concatenate([jnp.ravel(l) for l in
                                jax.tree_util.tree_leaves(params)])
        return {"pnorm": jnp.linalg.norm(flat)}

    dp = DPConfig(clients_per_round=12, noise_multiplier=0.3, clip_norm=0.8,
                  server_opt="momentum", server_lr=0.5, server_momentum=0.9)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    hists = {}
    for s in (1, num_shards):
        eng = SimEngine(model, ds.to_device_arrays(), dp, cl,
                        n_local_batches=2, availability=0.5,
                        rounds_per_call=2, num_shards=s,
                        eval_fn=eval_fn, eval_every=2)
        state = eng.init_state(model.init(jax.random.PRNGKey(1)), seed=0)
        _, hists[s] = eng.run(state, 4)
    np.testing.assert_array_equal(hists[1]["eval_mask"],
                                  hists[num_shards]["eval_mask"])
    np.testing.assert_array_equal(hists[1]["eval"]["pnorm"],
                                  hists[num_shards]["eval"]["pnorm"])


@pytest.mark.slow
def test_checkpoint_byte_parity_across_pods_and_shards(tmp_path,
                                                      monkeypatch):
    """End to end through the real CLI: `launch/train.py` runs with every
    {pods 1, 2} × {shards 1, 4} topology must write byte-identical
    checkpoints (sha256 over the .msgpack) — the strongest statement that
    the DP mechanism a launch ships is independent of the mesh it trained
    on."""
    import hashlib
    import sys
    from repro.launch import train as train_cli

    if len(jax.devices()) < 8:
        pytest.skip("needs 8 devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=16)")

    digests = {}
    for pods, shards in ((1, 1), (1, 4), (2, 1), (2, 4)):
        out = tmp_path / f"p{pods}s{shards}"
        argv = ["train", "--arch", "gboard-cifg-lstm", "--reduced",
                "--vocab", "64", "--rounds", "2", "--n-users", "40",
                "--clients-per-round", "8", "--noise-multiplier", "0.25",
                "--seq-len", "8", "--rounds-per-call", "2",
                "--num-pods", str(pods), "--num-shards", str(shards),
                "--seed", "0", "--out", str(out)]
        monkeypatch.setattr(sys, "argv", argv)
        train_cli.main()
        (ck,) = out.glob("*.msgpack")
        digests[(pods, shards)] = hashlib.sha256(ck.read_bytes()).hexdigest()
    assert len(set(digests.values())) == 1, digests


def test_canon_pad_grid():
    """The canonical grid is shard-count-invariant exactly where the parity
    suite claims it: every shard count dividing CANON_BLOCKS yields the
    same padded size (same reduction tree), and padding never shrinks."""
    for n in (1, 7, 8, 10, 12, 100, 1000):
        sizes = {canon_pad(n, s) for s in (1, 2, 4, 8)}
        assert len(sizes) == 1          # identical grid across the matrix
        (p,) = sizes
        assert p >= n and p % CANON_BLOCKS == 0
    assert canon_pad(12, 3) % 3 == 0    # non-canonical counts still align
