"""Data pipeline + FL runtime substrate tests."""
import numpy as np
import pytest

from repro.configs import ClientConfig, DPConfig
from repro.core.secret_sharer import make_canaries
from repro.data.corpus import BigramCorpus
from repro.data.federated import FederatedDataset, USER_SENTENCES
from repro.data.ngram import KatzTrigramLM, recall_at_k
from repro.data.tokenizer import PAD, Tokenizer
from repro.fl.population import PopulationSim, participation_rates
from repro.fl.sampling import fixed_size_sample, poisson_sample, sample_round

import jax

VOCAB = 1000


@pytest.fixture(scope="module")
def corpus():
    return BigramCorpus(vocab_size=VOCAB, seed=0)


def test_tokenizer_roundtrip():
    tok = Tokenizer(100)
    ids = tok.encode(["w0", "w5", "nope"])
    assert ids[2] == 1  # UNK
    assert tok.decode(ids)[:2] == ["w0", "w5"]


def test_corpus_learnable_structure(corpus):
    """Bigram oracle recall must far exceed unigram: there IS signal."""
    sents = corpus.sample_sentences(300, seed=1)
    hit = tot = 0
    for s in sents:
        for i in range(2, len(s) - 1):  # skip BOS-successor + EOS
            hit += int(s[i + 1] in corpus.bigram_topk(s[i], 3))
            tot += 1
    assert hit / tot > 0.5


def test_federated_dataset_caps(corpus):
    ds = FederatedDataset(corpus, n_users=20, seq_len=16,
                          sentences_per_user=500, max_examples_per_user=100)
    assert all(u.examples.shape[0] <= 100 for u in ds.users)


def test_canary_injection_matches_paper_grid(corpus):
    """Paper §IV-A: 27 canaries, 189 synthetic devices, n_e copies each."""
    ds = FederatedDataset(corpus, n_users=10, seq_len=16)
    canaries = make_canaries(jax.random.PRNGKey(0), vocab=VOCAB)
    assert len(canaries) == 27
    synth = ds.inject_canaries(canaries)
    assert len(synth) == 3 * 3 * (1 + 4 + 16)  # 189
    for shard in synth:
        assert shard.examples.shape[0] == USER_SENTENCES
        n_e = min(shard.canary.n_e, USER_SENTENCES)
        row = list(shard.canary.tokens)
        hits = sum(1 for ex in shard.examples
                   if list(ex[:len(row)]) == row)
        assert hits == n_e


def test_user_tensor_shapes(corpus):
    ds = FederatedDataset(corpus, n_users=4, seq_len=16)
    t = ds.user_tensor(0, batch_size=8, n_batches=3,
                       rng=np.random.default_rng(0))
    assert t["tokens"].shape == (3, 8, 16)
    assert t["mask"].shape == (3, 8, 16)
    assert (t["labels"][t["mask"] > 0] != PAD).all()


def test_to_device_arrays_packing(corpus):
    """Engine packing: shapes, true counts, tiled padding holds only real
    examples, synthetic mask mirrors the shards."""
    ds = FederatedDataset(corpus, n_users=6, seq_len=16,
                          sentences_per_user=5)
    ds.inject_canaries(make_canaries(jax.random.PRNGKey(0),
                                     vocab=VOCAB)[:1])
    data = ds.to_device_arrays()
    n, emax = data["examples"].shape[:2]
    assert n == len(ds.users)
    assert emax == max(u.examples.shape[0] for u in ds.users)
    assert data["examples"].shape[2] == 17
    for i, u in enumerate(ds.users):
        assert data["counts"][i] == u.examples.shape[0]
        assert data["synthetic"][i] == u.is_synthetic
        # every padded slot tiles a real example of the same user
        real = {tuple(r) for r in u.examples}
        assert all(tuple(r) in real for r in data["examples"][i])


def test_inject_canaries_rejects_shared_prefixes(corpus):
    """Hand-built canaries sharing a beam-search prefix are rejected —
    extraction would be ill-defined (make_canaries never produces them)."""
    ds = FederatedDataset(corpus, n_users=4, seq_len=16)
    from repro.core.secret_sharer import Canary
    a = Canary((1, 2, 3, 4, 5), 1, 1)
    b = Canary((1, 2, 9, 9, 9), 1, 1)   # same (1, 2) prefix
    with pytest.raises(ValueError, match="prefix"):
        ds.inject_canaries([a, b])


def test_canaries_accessor_order(corpus):
    ds = FederatedDataset(corpus, n_users=4, seq_len=16)
    cans = make_canaries(jax.random.PRNGKey(1), vocab=VOCAB,
                         grid=[(2, 3), (1, 5)], per_config=2)
    ds.inject_canaries(cans)
    assert ds.canaries() == cans


def test_canaries_survive_device_packing(corpus):
    """inject_canaries → to_device_arrays → engine gather: the injected
    tokens must come out of the padded corpus tensor and appear in the
    gathered client batches."""
    import jax.numpy as jnp
    from repro.core.secret_sharer import Canary
    from repro.fl.engine import gather_client_batches

    ds = FederatedDataset(corpus, n_users=6, seq_len=16,
                          sentences_per_user=5)
    full = Canary((11, 22, 33, 44, 55), 1, 200)   # all 200 examples = canary
    part = Canary((66, 77, 88, 99, 12), 1, 7)     # 7 canary + 193 public
    ds.inject_canaries([full, part])
    data = ds.to_device_arrays()
    uid_full, uid_part = 6, 7
    assert data["synthetic"][uid_full] and data["synthetic"][uid_part]

    row = list(full.tokens) + [PAD] * (17 - 5)
    assert all(list(r) == row for r in data["examples"][uid_full])
    part_rows = [list(r[:5]) for r in data["examples"][uid_part]]
    assert part_rows.count(list(part.tokens)) == 7

    batch = gather_client_batches(jnp.asarray(data["examples"]),
                                  jnp.asarray(data["counts"]),
                                  jnp.asarray([uid_full]),
                                  jax.random.split(jax.random.PRNGKey(0), 1),
                                  n_batches=2, batch_size=4)
    toks = np.asarray(batch["tokens"]).reshape(-1, 16)
    assert np.all(toks[:, :5] == np.asarray(full.tokens))
    labels = np.asarray(batch["labels"]).reshape(-1, 16)
    mask = np.asarray(batch["mask"]).reshape(-1, 16)
    # labels under the mask are the canary continuation, PAD masked out
    assert np.all(labels[:, :4] == np.asarray(full.tokens[1:]))
    assert np.all(mask[:, :4] == 1.0) and np.all(mask[:, 4:] == 0.0)


def test_ngram_beats_unigram(corpus):
    train = corpus.sample_sentences(3000, seed=2)
    test = corpus.sample_sentences(300, seed=3)
    lm = KatzTrigramLM(VOCAB).fit(train)
    r1 = recall_at_k(lm, test, 1)
    uni = KatzTrigramLM(VOCAB).fit([[w] for s in train for w in s])
    r_uni = recall_at_k(uni, test, 1)
    assert r1 > r_uni + 0.1


# ----------------------------- FL runtime ----------------------------------


def test_fixed_size_sample_exact():
    rng = np.random.default_rng(0)
    ids = np.arange(1000)
    s = fixed_size_sample(rng, ids, 50)
    assert len(s) == 50 and len(set(s)) == 50


def test_poisson_sample_mean():
    rng = np.random.default_rng(0)
    ids = np.arange(100_000)
    s = poisson_sample(rng, ids, 0.01)
    assert 800 < len(s) < 1200


def test_pace_steering_suppresses_repeats():
    """Recently-participating devices are strongly deprioritized; synthetic
    (canary) devices exempt — reproducing the paper's 1–2 order-of-magnitude
    participation gap (§IV-A / Table 3)."""
    n, synth = 2000, list(range(1990, 2000))
    pop = PopulationSim(n, availability=0.05, pace_cooldown=40,
                        synthetic_ids=synth, seed=0)
    rng = np.random.default_rng(0)
    part = np.zeros(n)
    for r in range(120):
        ids = sample_round(pop, rng, r, 20)
        part[ids] += 1
    real_rate = part[:1990].mean()
    synth_rate = part[1990:].mean()
    assert synth_rate > 10 * real_rate
    # the shared helper computes the same per-round rates (Table 3)
    mask = np.zeros(n, bool)
    mask[synth] = True
    s, r = participation_rates(part, mask, 120)
    assert s == pytest.approx(synth_rate / 120)
    assert r == pytest.approx(real_rate / 120)


def test_synthetic_always_checked_in():
    pop = PopulationSim(100, availability=0.0, synthetic_ids=[7, 9], seed=0)
    ids = pop.checked_in(0)
    assert set(ids) == {7, 9}
