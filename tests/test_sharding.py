"""Sharding spec coverage: every param/cache leaf gets a spec whose sharded
dims divide the production mesh axes — for all 10 assigned architectures ×
4 input shapes. Plus a 1×1-mesh lower+compile integration test on reduced
configs (real compile, no placeholder devices needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import (ASSIGNED_ARCHS, INPUT_SHAPES, MULTI_POD,
                           SINGLE_POD, get_config)
from repro.models import build
from repro.sharding import specs as SP
from repro.utils import compat

AX = dict(zip(SINGLE_POD.axes, SINGLE_POD.shape))
AX_MP = dict(zip(MULTI_POD.axes, MULTI_POD.shape))


def _check_divisible(tree_shapes, spec_tree, axes):
    def one(path, leaf, spec):
        assert len(spec) == leaf.ndim, (path, spec, leaf.shape)
        for dim, s in zip(leaf.shape, spec):
            if s is None:
                continue
            names = s if isinstance(s, tuple) else (s,)
            par = int(np.prod([axes[n] for n in names]))
            assert dim % par == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(one, tree_shapes, spec_tree)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_divisible(arch):
    cfg = get_config(arch)
    model = build(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = SP.param_specs(shapes, cfg, SINGLE_POD)
    _check_divisible(shapes, specs, AX)
    specs_mp = SP.param_specs(shapes, cfg, MULTI_POD)
    _check_divisible(shapes, specs_mp, AX_MP)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_divisible(arch, shape_name):
    from repro.launch.dryrun import arch_for_shape
    shape = INPUT_SHAPES[shape_name]
    cfg = arch_for_shape(get_config(arch), shape)
    model = build(cfg)
    cache_sh = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    specs = SP.cache_specs(cache_sh, cfg, shape, SINGLE_POD)
    _check_divisible(cache_sh, specs, AX)


def test_batch_specs_nondivisible_batch_replicates():
    cfg = get_config("phi3-mini-3.8b")
    long = INPUT_SHAPES["long_500k"]          # global_batch=1
    specs = SP.batch_specs(cfg, long, SINGLE_POD)
    assert specs["tokens"][0] is None


def test_mesh_configs():
    from repro.launch.mesh import mesh_config
    assert mesh_config().n_devices == 256
    assert mesh_config(multi_pod=True).n_devices == 512
    assert SP.batch_axis_size(MULTI_POD) == 32


@pytest.mark.parametrize("arch", [
    "granite-3-2b",
    pytest.param("olmoe-1b-7b", marks=pytest.mark.slow),
    pytest.param("mamba2-370m", marks=pytest.mark.slow),
    pytest.param("zamba2-2.7b", marks=pytest.mark.slow),
    pytest.param("whisper-small", marks=pytest.mark.slow),
])
def test_fed_train_step_compiles_1x1(arch):
    """Integration: the production fed_train_step lowers AND compiles on a
    real 1×1 CPU mesh with a reduced config (numerics exercised end-to-end
    by test_fed_step_numerics below). One dense representative stays in
    tier-1; the other families compile in the slow tier."""
    from repro.configs import DPConfig, MeshConfig
    from repro.configs.base import InputShape
    from repro.launch import steps as ST

    cfg = get_config(arch).reduced()
    model = build(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    mcfg = MeshConfig((1, 1), ("data", "model"))
    shape = InputShape("tiny_train", 16, 4, "train")
    params_sh = ST.params_shape(model)
    pspecs = SP.param_specs(params_sh, cfg, mcfg)
    with compat.set_mesh(mesh):
        fn = ST.make_fed_train_step(model, DPConfig(clients_per_round=4),
                                    mesh, mcfg, pspecs, shape, donate=False)
        opt_sh = ST.opt_state_shape(params_sh)
        inputs = ST.input_specs(cfg, shape)
        compiled = fn.lower(params_sh, opt_sh, inputs,
                            jax.ShapeDtypeStruct((2,), jnp.uint32)).compile()
    assert compiled is not None


def test_fed_step_numerics():
    """Run the jitted production fed_train_step with REAL values on the 1×1
    mesh: loss finite, params move, noise std respected."""
    from repro.configs import DPConfig, MeshConfig
    from repro.configs.base import InputShape
    from repro.core.server_optim import init_state
    from repro.launch import steps as ST

    cfg = get_config("granite-3-2b").reduced()
    model = build(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    mcfg = MeshConfig((1, 1), ("data", "model"))
    shape = InputShape("tiny_train", 16, 4, "train")
    params = model.init(jax.random.PRNGKey(0))
    pspecs = SP.param_specs(jax.eval_shape(model.init, jax.random.PRNGKey(0)),
                            cfg, mcfg)
    dp = DPConfig(clients_per_round=4, noise_multiplier=0.1, clip_norm=0.5)
    with compat.set_mesh(mesh):
        fn = ST.make_fed_train_step(model, dp, mesh, mcfg, pspecs, shape,
                                    donate=False)
        key = jax.random.PRNGKey(1)
        toks = jax.random.randint(key, (4, 17), 0, cfg.vocab)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        p0 = jax.tree_util.tree_map(lambda x: x.copy(), params)
        new_params, new_state, metrics = fn(params, init_state(params),
                                            batch, jax.random.PRNGKey(2))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["mean_update_norm"]) > 0
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p0, new_params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0
    assert int(new_state.count) == 1
