"""Durable checkpoint hardening: corrupt files surface `CheckpointError`
naming the path, missing files stay `FileNotFoundError`, and `save` is
atomic (a crash mid-save never destroys the previous durable state)."""
import numpy as np
import pytest

from repro.train import checkpoint


@pytest.fixture
def tree():
    return {"layer": {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                      "b": np.zeros(4, np.float32)},
            "opt": (np.ones(3, np.float32), np.int32(7))}


def test_roundtrip(tmp_path, tree):
    p = tmp_path / "ck.msgpack"
    checkpoint.save(p, tree, meta={"kind": "test"})
    loaded, meta = checkpoint.load(p)
    assert meta["kind"] == "test"
    np.testing.assert_array_equal(loaded["layer"]["w"], tree["layer"]["w"])
    assert isinstance(loaded["opt"], tuple)


def test_truncated_file_raises_checkpoint_error(tmp_path, tree):
    p = tmp_path / "ck.msgpack"
    checkpoint.save(p, tree)
    blob = p.read_bytes()
    p.write_bytes(blob[:len(blob) // 2])
    with pytest.raises(checkpoint.CheckpointError, match=str(p)):
        checkpoint.load(p)


def test_garbage_bytes_raise_checkpoint_error(tmp_path):
    p = tmp_path / "junk.msgpack"
    p.write_bytes(b"\x93not a checkpoint at all" * 10)
    with pytest.raises(checkpoint.CheckpointError, match="junk.msgpack"):
        checkpoint.load(p)


def test_missing_file_stays_file_not_found(tmp_path):
    # "resume from nothing" must be distinguishable from "state is damaged"
    with pytest.raises(FileNotFoundError):
        checkpoint.load(tmp_path / "never_written.msgpack")


def test_failed_save_preserves_previous_durable_file(tmp_path, tree,
                                                     monkeypatch):
    p = tmp_path / "ck.msgpack"
    checkpoint.save(p, tree, meta={"gen": "1"})
    import os
    real_replace = os.replace

    def boom(src, dst):
        raise OSError("disk died mid-publish")

    monkeypatch.setattr(os, "replace", boom)
    with pytest.raises(OSError, match="disk died"):
        checkpoint.save(p, {"layer": {"w": np.zeros(2, np.float32)}},
                        meta={"gen": "2"})
    monkeypatch.setattr(os, "replace", real_replace)
    loaded, meta = checkpoint.load(p)   # old state intact, still loadable
    assert meta["gen"] == "1"
    np.testing.assert_array_equal(loaded["layer"]["w"], tree["layer"]["w"])
    # and no temp litter survived the failure
    assert list(tmp_path.glob(".*.tmp.*")) == []
