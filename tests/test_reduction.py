"""Property tests for the canonical-reduction primitives at awkward
topologies.

The engine's CI matrix exercises the power-of-two bit-parity family
(``num_pods × num_shards`` dividing `CANON_BLOCKS`); these tests pin down
what the primitives guarantee *outside* it — shard counts 3, 5, 6, 7 and
pod counts {1, 2, 4} that don't divide the canonical grid: the block count
pads up so every boundary still lands on a block edge, nobody is ever
truncated, and padded slots contribute an exact zero. And inside the
family, `fold_pods`' two-level tree is proven bit-equal to the flat
`fold_blocks` — the re-bracketing identity the whole cross-pod parity grid
rests on.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl.reduction import (CANON_BLOCKS, block_sums, canon_pad,
                                cohort_sum, fold_blocks, fold_pods,
                                n_canon_blocks, resolve_chunk)

AWKWARD_SHARDS = (3, 5, 6, 7)
PODS = (1, 2, 4)


# ------------------------------------------------------- grid arithmetic


@pytest.mark.parametrize("num_pods", PODS)
@pytest.mark.parametrize("num_shards", AWKWARD_SHARDS)
def test_n_canon_blocks_awkward_topologies(num_shards, num_pods):
    """The block count is the smallest multiple of the total shard count
    ≥ CANON_BLOCKS whenever the total doesn't divide CANON_BLOCKS — both
    pod and shard boundaries land on block boundaries, at minimal padding."""
    total = num_shards * num_pods
    nb = n_canon_blocks(num_shards, num_pods)
    assert nb % total == 0                    # boundaries align
    assert nb % num_pods == 0                 # whole blocks per pod
    assert nb >= CANON_BLOCKS                 # never coarser than canonical
    assert nb - total < CANON_BLOCKS or nb == total  # minimal padding
    if CANON_BLOCKS % total == 0:
        assert nb == CANON_BLOCKS             # the bit-parity regime


@pytest.mark.parametrize("num_pods", PODS)
@pytest.mark.parametrize("num_shards", AWKWARD_SHARDS)
@pytest.mark.parametrize("n", (1, 7, 10, 40, 333))
def test_canon_pad_never_truncates(n, num_shards, num_pods):
    """The padded buffer holds every one of the n devices (pad ≥ n), splits
    into whole blocks, and each of the total shards gets the same whole
    number of slots — no remainder anywhere to silently drop."""
    total = num_shards * num_pods
    nb = n_canon_blocks(num_shards, num_pods)
    p = canon_pad(n, num_shards, num_pods)
    assert p >= n
    assert p % nb == 0 and p % total == 0
    # minimality: one block less would not fit n (or violate alignment)
    assert p - nb < max(n, 1)


@pytest.mark.parametrize("num_pods", PODS)
@pytest.mark.parametrize("num_shards", AWKWARD_SHARDS)
def test_resolve_chunk_divides_awkward_blocks(num_shards, num_pods):
    """Auto-resolved chunks divide the block size of every awkward grid, so
    the streaming fold's chunk boundaries stay inside block boundaries."""
    nb = n_canon_blocks(num_shards, num_pods)
    for cohort in (10, 24, 100):
        blk = canon_pad(cohort, num_shards, num_pods) // nb
        c = resolve_chunk(None, blk)
        assert c >= 1 and blk % c == 0
        # strict mode still rejects non-divisors on these grids
        if blk > 1:
            with pytest.raises(ValueError):
                resolve_chunk(blk + 1, blk)


def test_validation_errors():
    for bad in (0, -1):
        with pytest.raises(ValueError):
            n_canon_blocks(bad, 1)
        with pytest.raises(ValueError):
            n_canon_blocks(1, bad)
    with pytest.raises(ValueError, match="divide the block count"):
        fold_pods(jnp.zeros((8, 3)), num_pods=3)


# --------------------------------------------------- fold_pods identity


@pytest.mark.parametrize("num_pods", (1, 2, 4, 8))
def test_fold_pods_rebracketing_identity(num_pods):
    """Inside the parity family (power-of-two pod counts dividing the block
    count) fold_pods is bit-equal to the flat fold_blocks: a pod partial is
    an internal node of the balanced tree. This is the identity that makes
    the engine's hierarchical cross-pod reduction a no-op on the bits."""
    blocks = jax.random.normal(jax.random.PRNGKey(0), (CANON_BLOCKS, 37))
    np.testing.assert_array_equal(
        np.asarray(fold_pods(blocks, num_pods)),
        np.asarray(fold_blocks(blocks)))


def test_fold_pods_nondividing_grid_is_self_stable():
    """Outside the power-of-two regime (12 blocks, 4 pods of 3) the two-
    level fold is a *different* association from the flat fold — documented
    behaviour: awkward grids are only bit-stable against themselves."""
    blocks = jax.random.normal(jax.random.PRNGKey(1), (12, 5),
                               dtype=jnp.float32)
    a = np.asarray(fold_pods(blocks, 4))
    b = np.asarray(fold_pods(blocks, 4))
    np.testing.assert_array_equal(a, b)       # deterministic
    # and it still sums the same multiset of values (to float tolerance)
    np.testing.assert_allclose(a, np.asarray(blocks.sum(axis=0)), rtol=1e-5)


# -------------------------------------------- cohort_sum on awkward grids


@pytest.mark.parametrize("num_pods", PODS)
@pytest.mark.parametrize("num_shards", AWKWARD_SHARDS)
def test_cohort_sum_awkward_grid_counts_everybody(num_shards, num_pods):
    """On every awkward (shards, pods) grid the masked cohort sum counts
    each live slot exactly once (sum of a 0/1 indicator == live count) and
    padded/masked slots contribute exactly zero even when they hold
    garbage."""
    nb = n_canon_blocks(num_shards, num_pods)
    n = 26                                    # doesn't divide anything here
    padded = canon_pad(n, num_shards, num_pods)
    live = 19
    mask = jnp.arange(padded) < live
    # indicator tree: each live slot contributes exactly 1.0
    ones = {"x": jnp.ones((padded, 3))}
    out = cohort_sum(ones, mask, nb, num_pods)
    np.testing.assert_array_equal(np.asarray(out["x"]), float(live))
    # garbage in masked slots changes nothing, bitwise
    vals = jax.random.normal(jax.random.PRNGKey(2), (padded, 3))
    poisoned = {"x": jnp.where(mask[:, None], vals, 1e30)}
    clean = {"x": vals * mask[:, None]}
    np.testing.assert_array_equal(
        np.asarray(cohort_sum(poisoned, mask, nb, num_pods)["x"]),
        np.asarray(cohort_sum(clean, mask, nb, num_pods)["x"]))


def test_cohort_sum_parity_family_is_one_bit_class():
    """Every (shards, pods) topology whose total divides CANON_BLOCKS
    produces the same bits from cohort_sum — the single-device statement of
    the engine's cross-topology acceptance grid."""
    padded = canon_pad(26)                    # same grid for the family
    mask = jnp.arange(padded) < 26
    vals = {"x": jax.random.normal(jax.random.PRNGKey(3), (padded, 4))}
    ref = np.asarray(cohort_sum(vals, mask, CANON_BLOCKS, 1)["x"])
    fam = [(s, p) for s, p in itertools.product((1, 2, 4, 8), (1, 2, 4, 8))
           if CANON_BLOCKS % (s * p) == 0]
    assert len(fam) > 5
    for s, p in fam:
        assert canon_pad(26, s, p) == padded
        got = np.asarray(cohort_sum(vals, mask, n_canon_blocks(s, p), p)["x"])
        np.testing.assert_array_equal(got, ref, err_msg=f"shards={s} pods={p}")


def test_block_sums_partition():
    """block_sums partitions: block partials sum (in any order) to the same
    total the flat sum gives, to float tolerance, on a non-dividing grid."""
    a = jax.random.normal(jax.random.PRNGKey(4), (24, 6))
    for nb in (3, 6, 12):
        np.testing.assert_allclose(np.asarray(block_sums(a, nb).sum(axis=0)),
                                   np.asarray(a.sum(axis=0)), rtol=1e-5)
