"""Streaming chunked cohort accumulation ↔ materializing path parity.

The engine's round sum is accumulated `cohort_chunk` clients at a time
(`fl.client.stream_block_sums`): per canonical block, chunks fold
sequentially slot-by-slot, so the association — and hence the trajectory —
is *bit-identical across every chunk size dividing the block size*, at zero
noise and under σ>0, composing with the cross-shard parity of PR 3. That
invariance is what lets the memory knob (O(chunk) peak update buffers
instead of O(cohort)) be turned freely without touching the DP mechanism:
the clipped-sum sensitivity S/(qN) is association-independent only if the
association actually stays fixed.

The fused Pallas dp_clip clip→accumulate (`clip_path="fused"`, interpret
mode on CPU) is validated against the `clip_by_global_norm` pytree
reference (`clip_path="tree"`) and against the legacy materializing path
(`cohort_chunk=0`).

Shard-composition cases need forced devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest -q tests/test_engine_chunked.py
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ClientConfig, DPConfig, get_config
from repro.core.clipping import clip_by_global_norm
from repro.data.corpus import BigramCorpus
from repro.data.federated import FederatedDataset
from repro.fl.client import (chunk_accumulate, local_deltas, round_compute)
from repro.fl.engine import SimEngine, gather_client_batches
from repro.fl.reduction import auto_chunk, canon_pad, resolve_chunk
from repro.fl.round import FederatedTrainer
from repro.models import build

VOCAB = 300
ROUNDS = 2           # = rounds_per_call → one compiled scan per engine
COHORT = 32          # padded 32 → block size 4 → chunk grid {1, 2, 4}

needs = {s: pytest.mark.skipif(
    len(jax.devices()) < s,
    reason=f"needs {s} devices (XLA_FLAGS="
           f"--xla_force_host_platform_device_count=8)") for s in (2, 4, 8)}


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("gboard-cifg-lstm").with_(vocab=VOCAB, d_model=24,
                                               d_ff=48)
    model = build(cfg)
    corpus = BigramCorpus(vocab_size=VOCAB, seed=0)
    ds = FederatedDataset(corpus, n_users=80, seq_len=16,
                          sentences_per_user=20)
    return cfg, model, ds


@pytest.fixture(scope="module")
def runner(setup):
    """Memoized engine runs keyed by config — parity tests share runs."""
    _, model, ds = setup
    data = ds.to_device_arrays()
    cache = {}

    def run(chunk, *, noise=0.0, sampling="fixed", cohort=COHORT,
            num_shards=1, clip_path="fused"):
        key = (chunk, noise, sampling, cohort, num_shards, clip_path)
        if key not in cache:
            dp = DPConfig(clients_per_round=cohort, noise_multiplier=noise,
                          clip_norm=0.8, server_opt="momentum",
                          server_lr=0.5, server_momentum=0.9,
                          sampling=sampling)
            cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
            eng = SimEngine(
                model, data, dp, cl, n_local_batches=2,
                availability=1.0 if sampling == "poisson" else 0.6,
                rounds_per_call=2, cohort_chunk=chunk,
                num_shards=num_shards, clip_path=clip_path)
            state = eng.init_state(model.init(jax.random.PRNGKey(1)), seed=0)
            state, hist = eng.run(state, ROUNDS)
            cache[key] = (eng, state, hist)
        return cache[key]

    return run


def _max_leaf_diff(a, b):
    d = jax.tree_util.tree_map(
        lambda x, y: float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                           - y.astype(jnp.float32)))), a, b)
    return max(jax.tree_util.tree_leaves(d))


def _assert_bitwise(run_a, run_b):
    _, sa, ha = run_a
    _, sb, hb = run_b
    np.testing.assert_array_equal(ha["loss"], hb["loss"])
    np.testing.assert_array_equal(ha["mean_update_norm"],
                                  hb["mean_update_norm"])
    np.testing.assert_array_equal(ha["n_clients"], hb["n_clients"])
    np.testing.assert_array_equal(np.asarray(sa.participation),
                                  np.asarray(sb.participation))
    assert _max_leaf_diff(sa.params, sb.params) == 0.0
    assert _max_leaf_diff(sa.opt_state, sb.opt_state) == 0.0


# --------------------------------------------------- chunk-size invariance


@pytest.mark.parametrize("sampling,chunk", [
    ("fixed", 1), ("fixed", 2), ("poisson", 2),
])
def test_chunk_parity_bit_exact(runner, sampling, chunk):
    """Zero noise: every cohort_chunk dividing the block size — including
    chunk=1 and chunk=block — produces bit-identical trajectories. The
    reference is chunk=4 == the full block (cohort 32 → block size 4);
    cohort_chunk=None auto-resolution is unit-tested in
    test_resolve_and_auto_chunk and is the default everywhere else."""
    _assert_bitwise(runner(chunk, sampling=sampling),
                    runner(4, sampling=sampling))


@pytest.mark.parametrize("chunk", [1, 2])
def test_chunk_parity_survives_noise(runner, chunk):
    """σ > 0: the Gaussian draw happens once on the replicated stream after
    the streamed sum, so noised trajectories are chunk-size-invariant too."""
    _assert_bitwise(runner(chunk, noise=0.3), runner(4, noise=0.3))
    _, _, hist = runner(chunk, noise=0.3)
    np.testing.assert_allclose(hist["noise_std"], 0.3 * 0.8 / COHORT,
                               rtol=1e-6)


@pytest.mark.parametrize("num_shards,chunk", [
    pytest.param(2, 1, marks=needs[2]),
    pytest.param(4, 2, marks=needs[4]),
    pytest.param(8, 4, marks=needs[8]),
])
def test_chunk_shard_composition(runner, num_shards, chunk):
    """Chunking composes with the cohort-axis sharding: any (shard count
    dividing CANON_BLOCKS) × (chunk dividing the block size) grid point is
    bit-identical to the unsharded single-reference run — the S/(qN)
    sensitivity bound survives every aggregation topology unchanged."""
    _assert_bitwise(runner(chunk, num_shards=num_shards), runner(4))


def test_masked_padding_chunks_contribute_nothing(runner):
    """Ragged cohort (10 of padded 16): the padding slots form fully-masked
    chunks whose compute is skipped by the scalar cond — skipping must be
    bit-identical to computing-and-masking, and nobody real is dropped."""
    runs = {c: runner(c, cohort=10) for c in (1, 2)}
    for c, (eng, state, hist) in runs.items():
        assert eng.padded == canon_pad(10) == 16
        np.testing.assert_array_equal(hist["n_clients"], 10)
        assert int(np.asarray(state.participation).sum()) == ROUNDS * 10
    _assert_bitwise(runs[1], runs[2])


def test_chunk_accumulate_masked_slot_is_exact_zero(setup):
    """Unit: a zero mask keeps even extreme-magnitude deltas out of the
    accumulator bitwise (0·x = ±0 and acc + ±0 = acc), for both clip
    implementations."""
    _, model, _ = setup
    acc_tree = {"w": jnp.full((5, 3), 0.123, jnp.float32)}
    deltas = {"w": jnp.stack([jnp.full((5, 3), 1e15, jnp.float32),
                              jnp.full((5, 3), -1e15, jnp.float32)])}
    losses = jnp.array([3.0, 4.0])
    mask = jnp.zeros((2,))
    for path in ("fused", "tree"):
        (upd, stats) = jax.jit(
            lambda a: chunk_accumulate((a, jnp.zeros(4)), deltas, losses,
                                       mask, 0.8, clip_path=path))(acc_tree)
        np.testing.assert_array_equal(np.asarray(upd["w"]),
                                      np.asarray(acc_tree["w"]))
        np.testing.assert_array_equal(np.asarray(stats), 0.0)


# ------------------------------------------------- clip-path / legacy refs


def test_fused_clip_matches_tree_reference(runner):
    """The fused Pallas dp_clip path and the clip_by_global_norm pytree
    reference agree to float tolerance on whole trajectories (they differ
    only in the sum-of-squares association)."""
    _, sf, hf = runner(4)
    _, st, ht = runner(4, clip_path="tree")
    np.testing.assert_allclose(hf["loss"], ht["loss"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(hf["mean_update_norm"],
                               ht["mean_update_norm"], rtol=1e-5)
    np.testing.assert_allclose(hf["frac_clipped"], ht["frac_clipped"],
                               atol=1e-6)
    assert _max_leaf_diff(sf.params, st.params) < 1e-5


def test_streaming_matches_materializing(runner):
    """The streamed engine reproduces the legacy materializing engine
    (cohort_chunk=0) within float tolerance: same cohorts (bitwise
    participation), same trajectory up to reduction association."""
    _, ss, hs = runner(4)
    _, sm, hm = runner(0)
    np.testing.assert_array_equal(np.asarray(ss.participation),
                                  np.asarray(sm.participation))
    np.testing.assert_allclose(hs["loss"], hm["loss"], rtol=1e-5, atol=1e-6)
    assert _max_leaf_diff(ss.params, sm.params) < 1e-5


# --------------------------------------------------------- host round body


def test_round_compute_matches_engine_bitwise(setup):
    """The host round body streams through the *same* canonical association
    as the engine: given identical batches and mask, the clipped sums and
    stats are bit-equal — the property that keeps the host loop a true
    reference for the engine rather than an approximation."""
    _, model, ds = setup
    dp = DPConfig(clients_per_round=16, noise_multiplier=0.0, clip_norm=0.8)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    eng = SimEngine(model, ds.to_device_arrays(), dp, cl, n_local_batches=2,
                    availability=0.6, cohort_chunk=2)
    params = model.init(jax.random.PRNGKey(1))
    ids = jnp.arange(16)
    keys = jax.random.split(jax.random.PRNGKey(3), 16)
    mask = jnp.ones(16)
    batches = gather_client_batches(eng.examples, eng.counts, ids, keys,
                                    2, 10)
    total_e, scal_e = jax.jit(
        lambda p: eng._cohort_sums(p, ids, keys, mask))(params)
    total_h, mean_norm, _, loss = jax.jit(
        lambda p: round_compute(model, p, batches, cl, dp, mask=mask,
                                cohort_chunk=2))(params)
    assert _max_leaf_diff(total_e, total_h) == 0.0
    assert float(mean_norm) == float(scal_e[0] / 16)
    assert float(loss) == float(scal_e[2] / 16)


def test_round_compute_chunk_parity_and_reference(setup):
    """round_compute is chunk-size-invariant bitwise (a non-dividing request
    resolves leniently — the host's realized round size varies), and the
    streamed result matches the legacy materializing body to tolerance.
    C=11 exercises the pad-to-canonical-grid path (pad slots alias slot 0
    under a zero mask)."""
    _, model, ds = setup
    dp = DPConfig(clients_per_round=16, noise_multiplier=0.0, clip_norm=0.8)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    eng = SimEngine(model, ds.to_device_arrays(), dp, cl, n_local_batches=2,
                    availability=0.6)
    params = model.init(jax.random.PRNGKey(1))
    keys = jax.random.split(jax.random.PRNGKey(3), 11)
    batches = gather_client_batches(eng.examples, eng.counts,
                                    jnp.arange(11), keys, 2, 10)
    outs = {}
    for chunk in (1, 2, 16, 0):   # 11 pads to 16 → block size 2
        outs[chunk] = jax.jit(
            lambda p, c=chunk: round_compute(model, p, batches, cl, dp,
                                             cohort_chunk=c))(params)
    for chunk in (1, 16):
        assert _max_leaf_diff(outs[2][0], outs[chunk][0]) == 0.0
        for i in (1, 2, 3):
            assert float(outs[2][i]) == float(outs[chunk][i])
    np.testing.assert_allclose(np.asarray(outs[2][1]),
                               np.asarray(outs[0][1]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(outs[2][3]),
                               np.asarray(outs[0][3]), rtol=1e-5)
    assert _max_leaf_diff(outs[2][0], outs[0][0]) < 1e-5


def test_streamed_clip_matches_clip_by_global_norm(setup):
    """One client through the fused streaming accumulator == that client's
    clip_by_global_norm result (the validated reference), to tolerance."""
    _, model, ds = setup
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    eng_data = ds.to_device_arrays()
    examples = jnp.asarray(eng_data["examples"])
    counts = jnp.asarray(eng_data["counts"])
    params = model.init(jax.random.PRNGKey(1))
    keys = jax.random.split(jax.random.PRNGKey(3), 2)
    batches = gather_client_batches(examples, counts, jnp.arange(2), keys,
                                    2, 10)
    deltas, losses = jax.jit(
        lambda p: local_deltas(model, p, batches, cl))(params)
    acc0 = jax.tree_util.tree_map(
        lambda l: jnp.zeros(l.shape, jnp.float32), params)
    (upd, stats) = jax.jit(
        lambda d: chunk_accumulate((acc0, jnp.zeros(4)), d, losses,
                                   jnp.array([1.0, 0.0]), 0.8))(deltas)
    one = jax.tree_util.tree_map(lambda l: l[0], deltas)
    clipped, norm, flag = clip_by_global_norm(one, 0.8)
    assert _max_leaf_diff(upd, clipped) < 1e-6
    np.testing.assert_allclose(float(stats[0]), float(norm), rtol=1e-6)
    assert float(stats[3]) == 1.0


# ------------------------------------------------------- knobs / plumbing


def test_invalid_chunk_and_clip_path_raise(setup):
    """Non-dividing chunk sizes and unknown clip paths fail loudly at
    construction, naming the valid values."""
    _, model, ds = setup
    dp = DPConfig(clients_per_round=COHORT, noise_multiplier=0.0,
                  clip_norm=0.8)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    data = ds.to_device_arrays()
    with pytest.raises(ValueError, match="divide the canonical block"):
        SimEngine(model, data, dp, cl, cohort_chunk=3)   # block size 4
    with pytest.raises(ValueError, match="clip_path"):
        SimEngine(model, data, dp, cl, clip_path="nope")


def test_resolve_and_auto_chunk():
    """Chunk resolution: auto picks the largest divisor ≤ the cap; strict
    mode rejects non-divisors; lenient mode rounds down to a divisor."""
    assert auto_chunk(4) == 4
    assert auto_chunk(125) == 25
    assert auto_chunk(625) == 25
    assert auto_chunk(7) == 7 and auto_chunk(7, max_chunk=3) == 1
    assert resolve_chunk(None, 125) == 25
    assert resolve_chunk(5, 125) == 5
    assert resolve_chunk(0, 125) == 0       # materializing-path sentinel
    assert resolve_chunk(100, 125, strict=False) == 25
    with pytest.raises(ValueError, match="valid values"):
        resolve_chunk(100, 125)
    with pytest.raises(ValueError, match="divide"):
        resolve_chunk(-1, 4, strict=False)


def test_trainer_chunk_plumbing(setup):
    """FederatedTrainer forwards cohort_chunk to both backends; engine
    trajectories stay bit-identical across chunk sizes end to end."""
    _, model, ds = setup
    dp = DPConfig(clients_per_round=12, noise_multiplier=0.0, clip_norm=0.8,
                  server_opt="momentum", server_lr=0.5, server_momentum=0.9)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    losses = {}
    for chunk in (1, 2):    # cohort 12 pads to 16 → block size 2
        tr = FederatedTrainer(model, ds, dp, cl, n_local_batches=2, seed=0,
                              backend="engine", rounds_per_call=2,
                              cohort_chunk=chunk)
        tr.train(2)
        losses[chunk] = [r["loss"] for r in tr.state.history]
    assert losses[1] == losses[2]
    # host backend accepts the knob too (chunk re-resolves per round shape)
    tr = FederatedTrainer(model, ds, dp, cl, n_local_batches=2, seed=0,
                          backend="host", cohort_chunk=2)
    tr.train(1)
    assert tr.state.history[-1]["n_clients"] > 0
    assert np.isfinite(tr.state.history[-1]["loss"])
