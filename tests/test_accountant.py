"""Accountant validation against the paper's Table 5 + RDP properties.

Property-style invariants are checked over fixed deterministic parameter
grids (no hypothesis dependency — same invariants, reproducible points).
"""
import math

import pytest

from repro.core.accountant import (MomentsAccountant, eps_from_rdp,
                                   rdp_subsampled_gaussian,
                                   rdp_subsampled_gaussian_wor, table5_epsilon)

TABLE5 = {2_000_000: 9.86, 3_000_000: 6.73, 4_000_000: 5.36,
          5_000_000: 4.54, 10_000_000: 3.27}


@pytest.mark.parametrize("N,eps_paper", sorted(TABLE5.items()))
def test_table5_bracketed(N, eps_paper):
    """The paper used the WBK19 fixed-size-w/o-replacement accountant; our
    Poisson bound should come in below the paper's ε and our WBK19 Thm-9
    bound within ~15% of it (the paper's exact variant is slightly tighter
    at small N, slightly looser at large N)."""
    eps_poisson = table5_epsilon(N, sampling="poisson")
    eps_wor = table5_epsilon(N, sampling="wor")
    assert eps_poisson < eps_paper
    assert abs(eps_wor - eps_paper) / eps_paper < 0.16


def test_epsilon_decreases_with_population():
    eps = [table5_epsilon(N) for N in sorted(TABLE5)]
    assert all(a > b for a, b in zip(eps, eps[1:]))


def test_composition_additive():
    acc = MomentsAccountant(q=0.005, noise_multiplier=0.8)
    acc.step(100)
    e100 = acc.get_epsilon(1e-8)
    e200 = acc.get_epsilon(1e-8, rounds=200)
    assert e200 > e100
    assert acc.rounds == 100


@pytest.mark.parametrize("q", [1e-4, 1e-3, 5e-3, 0.02, 0.05])
@pytest.mark.parametrize("z", [0.3, 0.8, 1.7, 4.0])
@pytest.mark.parametrize("order", [2, 3, 8, 31, 64])
def test_rdp_properties(q, z, order):
    """RDP of the subsampled mechanism is positive, increasing in order,
    and below the unsubsampled Gaussian RDP (amplification, Poisson)."""
    r = rdp_subsampled_gaussian(q, z, order)
    r_next = rdp_subsampled_gaussian(q, z, order + 1)
    base = order / (2 * z * z)
    assert 0.0 <= r <= base + 1e-9
    assert r_next >= r - 1e-12


@pytest.mark.parametrize("q", [1e-4, 1e-3, 5e-3, 0.02])
@pytest.mark.parametrize("z", [0.5, 0.8, 1.3, 2.0])
def test_wor_at_least_poisson(q, z):
    """The replace-one WOR bound should not be tighter than Poisson here."""
    orders = list(range(2, 64))
    rp = [rdp_subsampled_gaussian(q, z, a) * 500 for a in orders]
    rw = [rdp_subsampled_gaussian_wor(q, z, a) * 500 for a in orders]
    ep, _ = eps_from_rdp(orders, rp, 1e-7)
    ew, _ = eps_from_rdp(orders, rw, 1e-7)
    assert ew >= ep * 0.999


def test_noise_multiplier_from_paper_sigma():
    """z = σ·qN/S: the paper's σ=3.2e-5 with qN=20000, S=0.8 ⇒ z=0.8."""
    from repro.configs import DPConfig
    dp = DPConfig()
    assert abs(dp.noise_std - 3.2e-5) < 1e-12


# --------------------------- production fault protocol (variable round sizes)

def test_record_round_composes_committed_only():
    """Interleaved commits and aborts: the composed ε equals a clean run of
    only the committed rounds — an aborted round released nothing, so it
    composes nothing."""
    acc = MomentsAccountant(q=0.005, noise_multiplier=0.8)
    pattern = [True, False, True, True, False, False, True] * 10
    for committed in pattern:
        acc.record_round(committed)
    n_committed = sum(pattern)
    assert acc.rounds == n_committed
    ref = MomentsAccountant(q=0.005, noise_multiplier=0.8)
    ref.step(n_committed)
    assert acc.get_epsilon(1e-8) == ref.get_epsilon(1e-8)


def test_aborted_rounds_spend_zero_budget():
    acc = MomentsAccountant(q=0.005, noise_multiplier=0.8)
    e0 = acc.get_epsilon(1e-8, rounds=0)
    for _ in range(50):
        acc.record_round(committed=False)
    assert acc.rounds == 0
    assert acc.get_epsilon(1e-8) == e0


def test_restore_rounds_round_trips():
    acc = MomentsAccountant(q=0.005, noise_multiplier=0.8)
    acc.step(123)
    eps = acc.get_epsilon(1e-8)
    fresh = MomentsAccountant(q=0.005, noise_multiplier=0.8)
    fresh.restore_rounds(acc.rounds)
    assert fresh.rounds == 123 and fresh.get_epsilon(1e-8) == eps
    with pytest.raises(ValueError):
        fresh.restore_rounds(-1)


def test_epsilon_monotone_in_dropout():
    """Higher dropout ⇒ fewer committed rounds ⇒ no more ε. Uses the real
    fault stream with monotone coupling (same uniforms, higher threshold ⇒
    the dropped set only grows, so the committed indicator is pointwise
    non-increasing in dropout), with over-selection off so dropout actually
    shrinks rounds."""
    import jax
    import numpy as np
    from repro.fl.faults import FaultConfig, fault_fates

    target, goal, rounds = 16, 12, 40
    eps = []
    for p in (0.0, 0.3, 0.6, 0.9):
        cfg = FaultConfig(seed=0, dropout_prob=p, over_select=False,
                          report_goal=goal)
        key = jax.random.PRNGKey(cfg.seed)
        acc = MomentsAccountant(q=0.005, noise_multiplier=0.8)
        for r in range(rounds):
            survivors = int(np.sum(np.asarray(
                fault_fates(key, r, target, cfg).reported)))
            acc.record_round(committed=survivors >= goal)
        eps.append(acc.get_epsilon(1e-8))
    assert all(a >= b for a, b in zip(eps, eps[1:]))
    assert eps[0] > eps[-1]          # 90% dropout really does abort rounds
