"""Streamed population backend ↔ device-resident backend parity.

`SimEngine(population_backend="streamed")` keeps the corpus on the host
(PopulationStore) and stages one cohort per round into two ping-ponged
device buffers, turning the K-round ``lax.scan`` into a host-driven loop
over a jitted sample body and a jitted compute body. The headline contract:
**trajectories are bit-exact against the device-resident backend** — the
sample body replays `_round_body`'s exact PRNG splits (same cohorts, same
per-slot batch keys, same noise keys), and the staged buffer satisfies
``cohort_examples[slot] == examples[ids[slot]]``, so every downstream draw
and gather is bit-identical. That parity must *compose* with the existing
invariances: chunk sizes dividing the canonical block size, the
materializing ``cohort_chunk=0`` path, every (pods, shards) topology in the
bit-parity family, fixed and Poisson sampling, σ=0 and σ>0, and the mmap
on-disk store.

Shard/pod cases need forced devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest -q tests/test_engine_streamed.py
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ClientConfig, DPConfig, get_config
from repro.data.corpus import BigramCorpus
from repro.data.federated import FederatedDataset
from repro.data.population_store import (InMemoryPopulationStore,
                                         ReplicatedPopulationStore,
                                         write_population_store)
from repro.fl.engine import (SimEngine, gather_client_batches,
                             gather_cohort_batches)
from repro.models import build

VOCAB = 300
ROUNDS = 2
COHORT = 32          # padded 32 → block size 4 → chunk grid {1, 2, 4}

needs = {s: pytest.mark.skipif(
    len(jax.devices()) < s,
    reason=f"needs {s} devices (XLA_FLAGS="
           f"--xla_force_host_platform_device_count=8)") for s in (2, 4, 8)}


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("gboard-cifg-lstm").with_(vocab=VOCAB, d_model=24,
                                               d_ff=48)
    model = build(cfg)
    corpus = BigramCorpus(vocab_size=VOCAB, seed=0)
    ds = FederatedDataset(corpus, n_users=80, seq_len=16,
                          sentences_per_user=20)
    return cfg, model, ds


@pytest.fixture(scope="module")
def mmap_store(setup, tmp_path_factory):
    _, _, ds = setup
    store = InMemoryPopulationStore.from_dataset(ds)
    path = write_population_store(
        tmp_path_factory.mktemp("pop") / "store", store, shard_users=23)
    return str(path)


@pytest.fixture(scope="module")
def runner(setup, mmap_store):
    """Memoized engine runs keyed by config; the device-backend reference
    run for a config is shared across every streamed comparison."""
    _, model, ds = setup
    data = ds.to_device_arrays()
    cache = {}

    def run(backend, *, noise=0.0, sampling="fixed", chunk=None,
            num_shards=1, num_pods=1, store="memory", entry="run",
            eval_fn=None):
        key = (backend, noise, sampling, chunk, num_shards, num_pods,
               store, entry, eval_fn is not None)
        if key not in cache:
            dp = DPConfig(clients_per_round=COHORT, noise_multiplier=noise,
                          clip_norm=0.8, server_opt="momentum",
                          server_lr=0.5, server_momentum=0.9,
                          sampling=sampling)
            cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
            src = data if backend == "device" else (
                mmap_store if store == "mmap"
                else InMemoryPopulationStore.from_arrays(data))
            eng = SimEngine(
                model, src, dp, cl, n_local_batches=2,
                availability=1.0 if sampling == "poisson" else 0.6,
                rounds_per_call=ROUNDS, cohort_chunk=chunk,
                num_shards=num_shards, num_pods=num_pods,
                population_backend=backend, eval_fn=eval_fn)
            state = eng.init_state(model.init(jax.random.PRNGKey(1)), seed=0)
            state, hist = getattr(eng, entry)(state, ROUNDS)
            cache[key] = (eng, state, hist)
        return cache[key]

    return run


def _max_leaf_diff(a, b):
    d = jax.tree_util.tree_map(
        lambda x, y: float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                           - y.astype(jnp.float32)))), a, b)
    return max(jax.tree_util.tree_leaves(d))


def _assert_bitwise(run_a, run_b):
    _, sa, ha = run_a
    _, sb, hb = run_b
    for k in ("loss", "mean_update_norm", "n_clients", "noise_std"):
        np.testing.assert_array_equal(np.asarray(ha[k]), np.asarray(hb[k]))
    np.testing.assert_array_equal(np.asarray(sa.participation),
                                  np.asarray(sb.participation))
    np.testing.assert_array_equal(np.asarray(sa.last_round),
                                  np.asarray(sb.last_round))
    np.testing.assert_array_equal(np.asarray(sa.key), np.asarray(sb.key))
    assert _max_leaf_diff(sa.params, sb.params) == 0.0
    assert _max_leaf_diff(sa.opt_state, sb.opt_state) == 0.0


# ------------------------------------------------- headline backend parity

def test_streamed_matches_device_zero_noise(runner):
    _assert_bitwise(runner("device"), runner("streamed"))


def test_streamed_matches_device_with_noise(runner):
    # σ>0: finalize_round's gaussian uses the same k_noise stream per round
    _assert_bitwise(runner("device", noise=0.3),
                    runner("streamed", noise=0.3))


def test_streamed_matches_device_poisson(runner):
    # variable-size rounds: padded buffer, mask from poisson_select
    _assert_bitwise(runner("device", sampling="poisson", noise=0.3),
                    runner("streamed", sampling="poisson", noise=0.3))


def test_streamed_mmap_store_matches_device(runner):
    # full path through the on-disk sharded mmap format
    _assert_bitwise(runner("device"), runner("streamed", store="mmap"))


def test_streamed_run_python_matches_run(runner):
    # donating prefetch loop vs non-donating stage-then-compute reference:
    # same PRNG streams, different dispatch order
    _assert_bitwise(runner("streamed"),
                    runner("streamed", entry="run_python"))


# ------------------------------------------- composition with PR-4 chunking

def test_streamed_chunk1_matches_device(runner):
    _assert_bitwise(runner("device", chunk=1), runner("streamed", chunk=1))


def test_streamed_materialize_matches_device(runner):
    # cohort_chunk=0: the materializing (non-streaming-accumulation) path
    # also works from a staged cohort buffer
    _assert_bitwise(runner("device", chunk=0), runner("streamed", chunk=0))


@pytest.mark.slow
@pytest.mark.parametrize("chunk", [2, 4])
def test_streamed_chunk_grid(runner, chunk):
    _assert_bitwise(runner("device", chunk=chunk),
                    runner("streamed", chunk=chunk))


# --------------------------------------- composition with sharded topologies

@needs[2]
def test_streamed_sharded_matches_device(runner):
    _assert_bitwise(runner("device", num_shards=2),
                    runner("streamed", num_shards=2))


@needs[4]
def test_streamed_pods_matches_device(runner):
    # 2-D (pod, data) mesh: the staged buffer device_puts with the cohort
    # NamedSharding, so shard_map sees the same layout as the device gather
    _assert_bitwise(runner("device", num_pods=2, num_shards=2),
                    runner("streamed", num_pods=2, num_shards=2))


@pytest.mark.slow
@needs[8]
def test_streamed_pods_wide(runner):
    _assert_bitwise(runner("device", num_pods=2, num_shards=4),
                    runner("streamed", num_pods=2, num_shards=4))


@needs[2]
def test_streamed_sharded_matches_unsharded_streamed(runner):
    # the canonical-reduction invariance holds within the streamed backend
    _assert_bitwise(runner("streamed"), runner("streamed", num_shards=2))


# ------------------------------------------------------------ eval-fn hook

def test_streamed_eval_hook_matches_device(runner):
    def eval_fn(params, round_idx):
        return {"l2": sum(jnp.sum(l.astype(jnp.float32) ** 2)
                          for l in jax.tree_util.tree_leaves(params))}

    dev = runner("device", eval_fn=eval_fn)
    stm = runner("streamed", eval_fn=eval_fn)
    _assert_bitwise(dev, stm)
    np.testing.assert_array_equal(np.asarray(dev[2]["eval"]["l2"]),
                                  np.asarray(stm[2]["eval"]["l2"]))
    np.testing.assert_array_equal(np.asarray(dev[2]["eval_mask"]),
                                  np.asarray(stm[2]["eval_mask"]))


# ----------------------------------------------------- unit-level contracts

def test_gather_cohort_batches_matches_client_batches(setup):
    """Slot-indexed batching over a staged cohort buffer == id-indexed
    batching over the resident corpus, given buffer[slot] = corpus[ids[slot]]
    and the same per-slot keys."""
    _, _, ds = setup
    data = ds.to_device_arrays()
    ex = jnp.asarray(data["examples"])
    cnt = jnp.asarray(data["counts"])
    ids = jnp.asarray([5, 0, 17, 5, 63, 41])
    keys = jax.random.split(jax.random.PRNGKey(7), ids.shape[0])
    by_id = gather_client_batches(ex, cnt, ids, keys, 3, 4)
    by_slot = gather_cohort_batches(ex[ids], cnt[ids], keys, 3, 4)
    for k in by_id:
        np.testing.assert_array_equal(np.asarray(by_id[k]),
                                      np.asarray(by_slot[k]))


def test_streamed_frees_staging_buffers(runner):
    eng, _, _ = runner("streamed")
    assert eng._inflight == [None, None]
    assert eng.examples is None and eng.counts is None


@pytest.mark.slow
def test_replicated_store_runs_at_scale(setup):
    """A 10⁴-user replicated view trains through the streamed backend with
    only O(cohort) example rows ever resident on device."""
    _, model, ds = setup
    base = InMemoryPopulationStore.from_dataset(ds)
    store = ReplicatedPopulationStore(base, 10_000)
    dp = DPConfig(clients_per_round=COHORT, noise_multiplier=0.3,
                  clip_norm=0.8, server_opt="momentum", server_lr=0.5,
                  server_momentum=0.9)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    eng = SimEngine(model, store, dp, cl, n_local_batches=2,
                    availability=0.3, population_backend="streamed")
    state = eng.init_state(model.init(jax.random.PRNGKey(1)), seed=0)
    state, hist = eng.run(state, 3)
    assert np.asarray(state.participation).shape == (10_000,)
    assert np.all(np.isfinite(np.asarray(hist["loss"])))
