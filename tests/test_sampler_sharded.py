"""Sharded cohort sampler ↔ global sampler parity (the O(N) scaling core).

`SimEngine(sampler="sharded")` replaces the monolithic per-round selection
(global (N,) uniform/Gumbel vectors + flat top-k) with block-keyed per-shard
draws, per-shard top-k merged through the canonical tree, and O(cohort)
masked scatters into mesh-sharded population vectors. It must be *the same
mechanism* as the default sampler within its own seed — deterministic and
bit-exact across every {pods} × {shards} × {chunk} × {device, streamed} ×
{fixed, poisson} × {faults on/off} combination — while the default
``sampler="global"`` path stays byte-for-byte untouched.

Shard counts above the visible device count are skipped; run the full grid
on CPU with

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest -q tests/test_sampler_sharded.py

(the CI ``population`` leg does exactly this).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ClientConfig, DPConfig, get_config
from repro.data.corpus import BigramCorpus
from repro.data.federated import FederatedDataset
from repro.fl import pop_sampler
from repro.fl.engine import SAMPLERS, SimEngine
from repro.fl.faults import FaultConfig
from repro.fl.round import FederatedTrainer
from repro.models import build

VOCAB = 300
N_USERS = 80
ROUNDS = 4

needs = {s: pytest.mark.skipif(
    len(jax.devices()) < s,
    reason=f"needs {s} devices (XLA_FLAGS="
           f"--xla_force_host_platform_device_count=8)") for s in (2, 4, 8)}

# (num_shards, num_pods) grid — pod-major rank must reproduce the flat order
TOPOLOGIES = [pytest.param(s, p, marks=needs[s * p], id=f"{s}x{p}")
              for s, p in ((2, 1), (4, 1), (8, 1), (2, 2), (4, 2))]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("gboard-cifg-lstm").with_(vocab=VOCAB, d_model=24,
                                               d_ff=48)
    model = build(cfg)
    corpus = BigramCorpus(vocab_size=VOCAB, seed=0)
    ds = FederatedDataset(corpus, n_users=N_USERS, seq_len=16,
                          sentences_per_user=20)
    return cfg, model, ds


def _run(model, ds, *, sampler="global", num_shards=1, num_pods=1,
         sampling="fixed", backend="device", chunk=None, faults=None,
         cohort=12, rounds=ROUNDS, seed=0):
    dp = DPConfig(clients_per_round=cohort, noise_multiplier=0.0,
                  clip_norm=0.8, server_opt="momentum", server_lr=0.5,
                  server_momentum=0.9, sampling=sampling)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    eng = SimEngine(model, ds.to_device_arrays(), dp, cl, n_local_batches=2,
                    availability=1.0 if sampling == "poisson" else 0.5,
                    rounds_per_call=2, num_shards=num_shards,
                    num_pods=num_pods, cohort_chunk=chunk,
                    population_backend=backend, sampler=sampler,
                    fault_config=faults)
    state = eng.init_state(model.init(jax.random.PRNGKey(1)), seed=seed)
    state, hist = eng.run(state, rounds)
    return eng, state, hist


def _max_leaf_diff(a, b):
    d = jax.tree_util.tree_map(
        lambda x, y: float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                           - y.astype(jnp.float32)))), a, b)
    return max(jax.tree_util.tree_leaves(d))


def _assert_same_run(eng, state, hist, ref_state, ref_hist):
    """Bit-exactness of everything a sampler touches: realized cohorts,
    population vectors (sliced to real users — the sharded vectors carry
    n_pad rows), trajectories, and server state."""
    n = eng.n_users
    np.testing.assert_array_equal(hist["n_clients"], ref_hist["n_clients"])
    np.testing.assert_array_equal(hist["loss"], ref_hist["loss"])
    np.testing.assert_array_equal(
        np.asarray(state.participation)[:n],
        np.asarray(ref_state.participation)[:n])
    np.testing.assert_array_equal(
        np.asarray(state.last_round)[:n],
        np.asarray(ref_state.last_round)[:n])
    assert _max_leaf_diff(state.params, ref_state.params) == 0.0
    assert _max_leaf_diff(state.opt_state, ref_state.opt_state) == 0.0


@pytest.fixture(scope="module")
def baselines(setup):
    """sampler="sharded", num_shards=1 reference runs — the sharded sampler
    is a *different (equally exact) sampler family* than global (block-keyed
    streams), so its parity contract is across topologies within its own
    seed, not against the global stream."""
    _, model, ds = setup
    return {key: _run(model, ds, sampler="sharded", sampling=key)
            for key in ("fixed", "poisson")}


# ------------------------------------------------------------ default path

def test_default_sampler_is_global(setup):
    """Regression: the sampler knob defaults to the pre-existing global
    path — constructing an engine without it must not change anything."""
    _, model, ds = setup
    dp = DPConfig(clients_per_round=12, noise_multiplier=0.0, clip_norm=0.8)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    eng = SimEngine(model, ds.to_device_arrays(), dp, cl)
    assert eng.sampler == "global"
    assert SAMPLERS == ("global", "sharded")
    # global keeps the unpadded population axis: state vectors are (N,)
    state = eng.init_state(model.init(jax.random.PRNGKey(1)), seed=0)
    assert state.participation.shape == (N_USERS,)


def test_invalid_sampler_rejected(setup):
    _, model, ds = setup
    dp = DPConfig(clients_per_round=12, noise_multiplier=0.0, clip_norm=0.8)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    with pytest.raises(ValueError, match="sampler"):
        SimEngine(model, ds.to_device_arrays(), dp, cl, sampler="blocked")


def test_host_backend_rejects_sharded_sampler(setup):
    """The trainer's host backend has no mesh — asking it for the sharded
    sampler must fail loudly at construction."""
    _, model, ds = setup
    dp = DPConfig(clients_per_round=12, noise_multiplier=0.0, clip_norm=0.8)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    with pytest.raises(ValueError, match="engine"):
        FederatedTrainer(model, ds, dp, cl, backend="host",
                         sampler="sharded")


# --------------------------------------------------- sharded ≡ global grid

@pytest.mark.parametrize("sampling", ["fixed", "poisson"])
def test_sharded_is_a_valid_sampler(setup, baselines, sampling):
    """The sharded family realizes the same round protocol as global: fixed
    mode fills the cohort exactly; Poisson mode's realized sizes are the
    buffer-truncated Bernoulli counts; participation totals match the
    realized round sizes."""
    eng, state, hist = baselines[sampling]
    if sampling == "fixed":
        np.testing.assert_array_equal(hist["n_clients"], 12)
    else:
        assert (np.asarray(hist["n_clients"]) <= eng.padded).all()
        assert (np.asarray(hist["n_clients"]) > 0).all()
    assert int(np.asarray(state.participation).sum()) == \
        int(np.asarray(hist["n_clients"]).sum())
    # only real users are ever selected (padded rows are masked invalid)
    assert np.asarray(state.participation)[eng.n_users:].sum() == 0


@pytest.mark.parametrize("sampling", ["fixed", "poisson"])
@pytest.mark.parametrize("num_shards,num_pods", TOPOLOGIES)
def test_sharded_topology_grid_bit_exact(setup, baselines, num_shards,
                                         num_pods, sampling):
    """Every (pods, shards) topology × sampling mode: per-shard top-k +
    canonical merge (or per-shard Poisson packing + index-order merge) must
    select the identical cohort and land the identical trajectory."""
    _, model, ds = setup
    _, ref_state, ref_hist = baselines[sampling]
    eng, state, hist = _run(model, ds, sampler="sharded",
                            num_shards=num_shards, num_pods=num_pods,
                            sampling=sampling)
    _assert_same_run(eng, state, hist, ref_state, ref_hist)


@pytest.mark.parametrize("num_shards,num_pods",
                         [pytest.param(4, 2, marks=needs[8])])
def test_streamed_backend_parity(setup, baselines, num_shards, num_pods):
    """The host-driven streamed population backend shares `_sample_phase`
    with the device scan — sharded selection must be bit-exact there too."""
    _, model, ds = setup
    _, ref_state, ref_hist = baselines["fixed"]
    eng, state, hist = _run(model, ds, sampler="sharded",
                            num_shards=num_shards, num_pods=num_pods,
                            backend="streamed")
    _assert_same_run(eng, state, hist, ref_state, ref_hist)


@pytest.mark.parametrize("num_shards", [pytest.param(4, marks=needs[4])])
def test_chunked_rounds_parity(setup, baselines, num_shards):
    """cohort_chunk streaming composes with the sharded sampler: selection
    happens before the chunk scan, so chunking must not move a bit."""
    _, model, ds = setup
    _, ref_state, ref_hist = baselines["fixed"]
    eng, state, hist = _run(model, ds, sampler="sharded",
                            num_shards=num_shards, chunk=1)
    _assert_same_run(eng, state, hist, ref_state, ref_hist)


def test_seed_determinism(setup):
    """Same seed → identical sharded run; different seed → different
    cohorts (the sampler is deterministic in the seed, not degenerate)."""
    _, model, ds = setup
    _, sa, ha = _run(model, ds, sampler="sharded")
    _, sb, hb = _run(model, ds, sampler="sharded")
    np.testing.assert_array_equal(np.asarray(sa.participation),
                                  np.asarray(sb.participation))
    np.testing.assert_array_equal(ha["loss"], hb["loss"])
    _, sc, _ = _run(model, ds, sampler="sharded", seed=1)
    assert not np.array_equal(np.asarray(sa.participation),
                              np.asarray(sc.participation))


# ------------------------------------------------------------ fault model

@pytest.mark.parametrize("backend,num_shards,num_pods", [
    pytest.param("device", 8, 1, marks=needs[8], id="device-8x1"),
    pytest.param("streamed", 2, 2, marks=needs[4], id="streamed-2x2"),
])
def test_fault_model_composition(setup, backend, num_shards, num_pods):
    """Over-selection, dropout/straggler/corrupt fates, and report-goal
    accounting compose with sharded selection: fates are drawn from the
    replicated fault stream against the merged cohort, so the faulty
    trajectory matches the global sampler's bit-for-bit."""
    _, model, ds = setup
    faults = FaultConfig(seed=5, dropout_prob=0.2, straggler_prob=0.1,
                         corrupt_prob=0.1)
    _, ref_state, ref_hist = _run(model, ds, sampler="sharded",
                                  faults=faults, chunk=1)
    eng, state, hist = _run(model, ds, sampler="sharded", faults=faults,
                            num_shards=num_shards, num_pods=num_pods,
                            backend=backend, chunk=1)
    np.testing.assert_array_equal(hist["n_reported"], ref_hist["n_reported"])
    _assert_same_run(eng, state, hist, ref_state, ref_hist)


# --------------------------------------------- merge-identity property math

def _flat_lex_topk(skey, k):
    """Reference: global lex top-k on (score desc, user id asc) — exactly
    what `lax.top_k` over the flat sortable keys realizes (stable ties)."""
    n = skey.shape[0]
    order = np.lexsort((np.arange(n), -skey.astype(np.int64)))
    return order[:k].astype(np.int32)


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("shards", [2, 4, 8])
def test_merge_topk_equals_flat_topk_adversarial_ties(seed, shards):
    """Property: per-shard top-k + `merge_topk` == flat lex top-k, under
    adversarial weights — a tiny value set (including -0.0/0.0 and values a
    single ulp apart) so ties pile up across shard boundaries and the
    winner is decided by the user-id tie-break, not the scores."""
    rng = np.random.default_rng(seed)
    per, k = 32, 12
    n = shards * per
    base = np.array([-1.0, -0.0, 0.0, 0.25, np.nextafter(0.25, 1.0),
                     1.0, np.inf, -np.inf], np.float32)
    score = rng.choice(base, n).astype(np.float32)
    skey = np.asarray(pop_sampler.sortable_f32(jnp.asarray(score)))
    vals, gids = [], []
    for s in range(shards):
        v, li = jax.lax.top_k(jnp.asarray(skey[s * per:(s + 1) * per]), k)
        vals.append(v)
        gids.append((s * per + li).astype(jnp.int32))
    merged = pop_sampler.merge_topk(jnp.concatenate(vals),
                                    jnp.concatenate(gids), k)
    np.testing.assert_array_equal(np.asarray(merged),
                                  _flat_lex_topk(skey, k))


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("n,k", [(80, 12), (4096, 200), (51200, 200),
                                 (60_000, 200), (262_145, 16)])
def test_blocked_topk_is_bit_identical_to_lax_topk(seed, n, k):
    """`blocked_topk` (the chunk-max-pruned shard top-k) must return the
    *exact* `lax.top_k` output — values and stable lowest-index ties — on
    both sides of its pruning threshold, under heavy ties (a tiny value
    set) and non-chunk-aligned lengths (tail padding)."""
    rng = np.random.default_rng(seed)
    base = np.array([-(2 ** 31), -7, 0, 3, 3, 3, 9, 2 ** 31 - 1], np.int64)
    skey = jnp.asarray(rng.choice(base, n).astype(np.int32))
    vals, idx = pop_sampler.blocked_topk(skey, k)
    ref_vals, ref_idx = jax.lax.top_k(skey, k)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(ref_vals))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ref_idx))


@pytest.mark.parametrize("seed", range(4))
def test_poisson_pack_merge_equals_flat_packing(seed):
    """Property: per-shard `pack_selected` + `merge_poisson` == the global
    index-order packing with buffer truncation (`engine.poisson_select`
    semantics: slot_mask marks exactly the buffer-resident devices)."""
    rng = np.random.default_rng(seed)
    shards, per, buffer = 4, 40, 16
    n = shards * per
    sel = rng.random(n) < 0.2
    gids, counts = [], []
    for s in range(shards):
        g, c = pop_sampler.pack_selected(jnp.asarray(sel[s * per:(s + 1) * per]),
                                         buffer, s * per)
        gids.append(g)
        counts.append(c[None])
    ids, slot_mask = pop_sampler.merge_poisson(jnp.concatenate(gids),
                                               jnp.concatenate(counts),
                                               buffer)
    flat = np.nonzero(sel)[0][:buffer]
    expect = np.zeros(buffer, np.int32)
    expect[:flat.shape[0]] = flat
    np.testing.assert_array_equal(np.asarray(ids), expect)
    np.testing.assert_array_equal(np.asarray(slot_mask),
                                  np.arange(buffer) < flat.shape[0])


def test_sortable_f32_is_monotone():
    """`sortable_f32` is order-preserving over a hostile value set (signed
    zeros, denormals, ulp neighbours, ±inf): keys never invert a float
    comparison. -0.0 maps one key *below* 0.0 (distinct bit patterns) —
    a total order refinement that is identical on every shard, which is
    all the merge identity needs."""
    xs = np.array([-np.inf, -3e38, -1.0, -np.nextafter(0.0, 1.0), -0.0,
                   0.0, np.nextafter(0.0, 1.0), 0.25,
                   np.nextafter(0.25, 1.0), 1.0, 3e38, np.inf], np.float32)
    keys = np.asarray(pop_sampler.sortable_f32(jnp.asarray(xs)),
                      np.int64)
    # xs ascends (with float-equal neighbours): keys must never decrease,
    # and every strict float increase must be a strict key increase
    for i in range(len(xs) - 1):
        if xs[i] < xs[i + 1]:
            assert keys[i] < keys[i + 1], (xs[i], xs[i + 1])
        else:
            assert keys[i] <= keys[i + 1], (xs[i], xs[i + 1])


def test_pop_pad_topology_invariant():
    """The padded population axis is identical for every topology in the
    parity grid — the precondition for block-keyed draws landing on the
    same users everywhere."""
    for n in (7, 80, 100, 10**6 + 3):
        sizes = {pop_sampler.pop_pad(n, s, p)
                 for s, p in ((1, 1), (2, 1), (4, 1), (8, 1), (2, 2),
                              (4, 2))}
        assert len(sizes) == 1
        (pad,) = sizes
        assert pad >= n and pad % pop_sampler.n_pop_blocks() == 0
