"""End-to-end behaviour of the paper's system: DP-FedAvg training on a
simulated device population improves held-out loss; the accountant tracks
rounds; clipping statistics match the paper's qualitative Fig. 1 behaviour
(small S ⇒ everyone clipped)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ClientConfig, DPConfig, get_config
from repro.data.corpus import BigramCorpus
from repro.data.federated import FederatedDataset, held_out_batch
from repro.fl.round import FederatedTrainer
from repro.models import build
from repro.models.layers import lm_loss

VOCAB = 500


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("gboard-cifg-lstm").with_(vocab=VOCAB, d_model=32,
                                               d_ff=64)
    model = build(cfg)
    corpus = BigramCorpus(vocab_size=VOCAB, seed=0)
    ds = FederatedDataset(corpus, n_users=100, seq_len=16,
                          sentences_per_user=20)
    return cfg, model, corpus, ds


def _held_out_loss(cfg, model, params, corpus):
    hb = held_out_batch(corpus, 128, 16)
    logits = model.forward(params, {"tokens": jnp.asarray(hb["tokens"])})
    return float(lm_loss(logits, jnp.asarray(hb["labels"]), cfg.vocab,
                         jnp.asarray(hb["mask"])))


def test_dp_fedavg_end_to_end_improves(setup):
    """Trains on the compiled engine (the default multi-round path)."""
    cfg, model, corpus, ds = setup
    dp = DPConfig(clients_per_round=30, noise_multiplier=0.3, clip_norm=0.8,
                  server_opt="momentum", server_lr=0.5, server_momentum=0.9)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    from repro.fl.population import PopulationSim
    pop = PopulationSim(len(ds.users), availability=0.6, seed=0)
    tr = FederatedTrainer(model, ds, dp, cl, pop=pop, n_local_batches=2,
                          seed=0, backend="engine", rounds_per_call=10)
    before = _held_out_loss(cfg, model, tr.state.params, corpus)
    tr.train(20)
    after = _held_out_loss(cfg, model, tr.state.params, corpus)
    assert after < before - 1.0, (before, after)
    assert tr.accountant.rounds == 20
    eps = tr.accountant.get_epsilon(1e-5)
    assert 0 < eps < 1e4


def test_tiny_clip_norm_clips_everyone(setup):
    """Fig. 1: below a certain S nearly all clients are clipped."""
    cfg, model, corpus, ds = setup
    dp = DPConfig(clients_per_round=20, noise_multiplier=0.0,
                  clip_norm=0.001, server_lr=0.1)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    tr = FederatedTrainer(model, ds, dp, cl, n_local_batches=2, seed=1)
    rec = tr.run_round()
    assert rec["frac_clipped"] == 1.0


def test_huge_clip_norm_clips_noone(setup):
    cfg, model, corpus, ds = setup
    dp = DPConfig(clients_per_round=20, noise_multiplier=0.0,
                  clip_norm=1e6, server_lr=0.1)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    tr = FederatedTrainer(model, ds, dp, cl, n_local_batches=2, seed=1)
    rec = tr.run_round()
    assert rec["frac_clipped"] == 0.0


def test_fixed_size_rounds(setup):
    from repro.fl.population import PopulationSim
    cfg, model, corpus, ds = setup
    dp = DPConfig(clients_per_round=17, noise_multiplier=0.0, clip_norm=1.0)
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    pop = PopulationSim(len(ds.users), availability=0.5, seed=2)
    tr = FederatedTrainer(model, ds, dp, cl, pop=pop, n_local_batches=2,
                          seed=2)
    for _ in range(3):
        rec = tr.run_round()
        assert rec["n_clients"] == 17  # Algorithm 1: fixed-size rounds


def test_noise_perturbs_but_preserves_scale(setup):
    """Same data/seed, with vs without noise: params differ by ~σ-scale."""
    from repro.fl.population import PopulationSim
    cfg, model, corpus, ds = setup
    cl = ClientConfig(local_epochs=1, batch_size=10, lr=0.3)
    outs = {}
    for z in (0.0, 1.0):
        dp = DPConfig(clients_per_round=20, noise_multiplier=z,
                      clip_norm=0.8, server_opt="sgd", server_lr=1.0)
        # enough checked-in devices that the round really has qN=20 clients
        # (σ below assumes the full cohort)
        pop = PopulationSim(len(ds.users), availability=0.6, seed=3)
        tr = FederatedTrainer(model, ds, dp, cl, pop=pop, n_local_batches=2,
                              seed=3)
        tr.run_round()
        outs[z] = tr.state.params
    diffs = jax.tree_util.tree_map(lambda a, b: jnp.max(jnp.abs(a - b)),
                                   outs[0.0], outs[1.0])
    md = max(float(x) for x in jax.tree_util.tree_leaves(diffs))
    sigma = 1.0 * 0.8 / 20
    assert 0 < md < 10 * sigma
